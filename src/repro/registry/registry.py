"""Schema registry: compile-once, multi-tenant validation state.

The paper's deployment premise is that schemas change rarely while
traffic is huge, so compilation cost amortizes to zero (PAPER.md §1).  A
gateway hosts *many* endpoint schemas and versions; the registry owns
that estate:

- :meth:`SchemaRegistry.register` compiles a schema for an endpoint id,
  caching the ``(CompiledSchema, Validator, LocationTape)`` triple plus
  compile-time stats (:class:`SchemaStats`).  Repeated registration on
  one endpoint creates monotonically increasing *versions*; the latest
  version serves.
- the **linked tape** over all batchable active versions is built by
  ``registry/linker.py``, eagerly at registration/eviction time so the
  serving path never re-links, and *incrementally*: per-version
  :class:`~repro.registry.linker.TapeSegment` preparations are cached,
  so a hot-swap re-links N members as pure concatenation with N-1
  segments coming from cache.  The linked state is keyed by the tuple
  of batchable (endpoint, serving-version) members: no-op changes
  (re-registering an identical schema, evicting a non-serving version,
  touching sequential-only endpoints) keep the jitted serving validator
  alive.
- :meth:`validate_mixed` validates a heterogeneous batch (per-document
  endpoint ids) in **one** batched-executor launch over the linked
  tape; documents of unbatchable endpoints (or undecided rows) are
  reported ``decided=False`` for the caller to route to that endpoint's
  sequential validator (per-schema modern-spec semantics stay pinned to
  the sequential oracle).
"""

from __future__ import annotations

import copy
import os
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..analysis.normalize import AnalysisReport, analyze_schema
from ..analysis.subsume import compare as subsume_compare
from ..analysis.unroll import recommend_unroll_depth

from ..core import CompiledSchema, NaiveValidator, Validator, compile_schema
from ..core.batch_executor import BatchValidator
from ..core.outcomes import (
    BreakerConfig,
    CircuitBreaker,
    DocumentDepthError,
    GuardLimits,
    ValidationBudget,
    ValidationOutcome,
    ValidationTimeout,
    Verdict,
    fault_point,
    resource_guard,
)
from ..core.explain import FailureSite, keyword_of
from ..core.tape import DEFAULT_UNROLL_DEPTH, LocationTape, try_build_tape
from ..obs.metrics import MetricRegistry
from ..obs.profile import phase as _phase
from ..obs.trace import span as _span
from .linker import (
    LinkedTape,
    TapeSegment,
    group_signature,
    link_tapes,
    segment_tape,
    signature_label,
)

__all__ = [
    "SchemaStats",
    "SchemaEntry",
    "SchemaRegistry",
    "AdmitCounts",
    "LinkGroup",
    "RegistrationError",
    "WidenedSwapWarning",
]


class RegistrationError(RuntimeError):
    """A registration failed build/verify/link; the prior version serves."""


class WidenedSwapWarning(UserWarning):
    """A hot-swap candidate was *proven* to accept strictly more
    instances than the serving version (DESIGN.md §15): traffic the old
    schema rejected will start passing.  The swap proceeds -- widening
    is often intentional -- but the posture is surfaced here, in
    ``registry_swap_widened_total`` and in ``endpoint_stats()``."""


@dataclass
class AdmitCounts:
    """How a mixed stream's verdicts were produced (admit_mixed)."""

    batch_validated: int = 0  # decided by a linked-tape (group) launch
    undecided: int = 0  # batchable but past the depth budget -> fallback
    oversize: int = 0  # batchable but past the encoder node budget -> fallback
    unroll_overflow: int = 0  # recursion outran the $ref-unroll budget -> fallback
    fallback_validated: int = 0  # sequential verdicts (incl. all of the above)
    # fault-containment dispositions (DESIGN.md §11)
    rejected_guard: int = 0  # admission resource guard said no (pre-encode)
    error_isolated: int = 0  # per-document encode/launch/fallback error trapped
    timed_out: int = 0  # bounded fallback ran out of budget/deadline
    breaker_open: int = 0  # fallback suspended: endpoint degraded (guard-only)
    # per-link-group attribution (DESIGN.md §14): the same launch-path
    # counters above, keyed by the group whose launch produced them, so
    # a group-routed fallback is not misattributed to "the" linked tape
    per_group: Dict[str, Dict[str, int]] = field(default_factory=dict)

    _GROUP_KEYS = (
        "batch_validated",
        "undecided",
        "oversize",
        "unroll_overflow",
        "error_isolated",
    )

    def group(self, label: str) -> Dict[str, int]:
        g = self.per_group.get(label)
        if g is None:
            g = self.per_group[label] = {k: 0 for k in self._GROUP_KEYS}
        return g


@dataclass(frozen=True)
class LinkGroup:
    """One Â/M̂/horizon-compatible partition of the batchable members.

    Each group owns its own :class:`LinkedTape` and jitted
    :class:`BatchValidator`; the member-max window inflation (§8) is
    confined to members sharing the group's signature class instead of
    taxing the whole estate.
    """

    label: str  # e.g. "a4.m4.h4" -- stable, metrics-safe
    key: Tuple[int, int, int]  # pow2 classes of (Â, M̂, horizon)
    members: Tuple[str, ...]  # endpoints, registration order
    signature: Tuple[Tuple[str, int], ...]  # (endpoint, version) identity
    tape: LinkedTape
    validator: BatchValidator
    member_index: Dict[str, int]  # endpoint -> group-local schema id
    # endpoints whose segments are physically present in the linked tape.
    # With ``dedup_links`` structurally identical members (equal canonical
    # hash) share one representative segment, so this can be shorter than
    # ``members``; ``member_index`` maps every endpoint to its (possibly
    # shared) schema id.
    linked_members: Tuple[str, ...] = ()


@dataclass
class SchemaStats:
    """Compile-time facts recorded at registration (the amortized cost)."""

    compile_seconds: float
    tape_seconds: float
    instruction_count: int
    batchable: bool
    fallback_reason: str = ""
    n_locations: int = 0
    n_props: int = 0
    n_assertions: int = 0
    a_hat: int = 0
    k: int = 0
    horizon: int = 0
    # $ref-unroll facts: the depth budget the tape was built with and
    # how many frontier locations it carries (0 = fully flat schema)
    unroll_depth: int = 0
    n_frontier: int = 0
    # logical-applicator circuit facts (DESIGN.md §10)
    n_circuits: int = 0
    circ_depth: int = 0
    # ahead-of-time schema-algebra facts (DESIGN.md §15): what the
    # register()-time analysis pipeline proved and rewrote
    analysis_seconds: float = 0.0
    normalized: bool = False  # analysis changed the lowered schema
    pruned_branches: int = 0  # proven-unsat branches removed pre-tape
    folded_assertions: int = 0  # constants folded / bounds tightened / noops
    dedup_subgraphs: int = 0  # subgraphs shared with other serving members
    analysis_failure: str = ""  # analyzer bailed (original schema lowered)
    subsumption: str = ""  # last swap verdict vs prior serving version


@dataclass
class SchemaEntry:
    """One registered (endpoint, version) with its compiled artifacts."""

    endpoint: str
    version: int
    schema: Any
    compiled: CompiledSchema
    validator: Validator  # sequential oracle (modern-spec semantics)
    tape: Optional[LocationTape]  # None outside the structural subset
    stats: SchemaStats
    # schema-algebra artifacts (DESIGN.md §15).  ``schema`` above keeps
    # the schema exactly as submitted (the verbatim no-op check and the
    # sequential oracle pin to it); ``canonical`` is the normalized form
    # the tape was actually lowered from.
    canonical: Any = None
    canonical_hash: str = ""
    analysis: Optional[AnalysisReport] = None


class SchemaRegistry:
    """Register/version/evict compiled schemas; link them for batching."""

    def __init__(
        self,
        *,
        engine: str = "codegen",
        use_pallas: bool = False,
        layout: str = "csr",
        max_depth: int = 16,
        unroll_depth: Optional[int] = None,
        guard: GuardLimits = GuardLimits(),
        breaker: BreakerConfig = BreakerConfig(),
        fallback_max_steps: int = 500_000,
        fallback_deadline_s: Optional[float] = 0.25,
        clock: Callable[[], float] = time.monotonic,
        metrics: Optional[MetricRegistry] = None,
        link_grouping: bool = True,
        analysis: bool = True,
        dedup_links: bool = True,
    ):
        self.engine = engine
        self.use_pallas = use_pallas
        self.layout = layout
        self.max_depth = max_depth
        # $ref-unroll sizing (DESIGN.md §15): None = auto -- honor the
        # REPRO_UNROLL_DEPTH env override, else size per schema from the
        # analyzer's recursion-cycle bound; an explicit int pins every
        # registration to that depth (legacy behavior).
        self.unroll_depth = unroll_depth
        # ahead-of-time schema algebra (DESIGN.md §15): normalize/prune
        # before lowering, prove swap subsumption, dedup linked segments
        self.analysis = analysis
        self.dedup_links = dedup_links
        # fault-containment knobs (DESIGN.md §11): admission guards,
        # bounded-fallback budget, and per-endpoint breaker config.  The
        # clock is injectable so breaker trips/recoveries test
        # deterministically.
        self.guard = guard
        self.breaker_cfg = breaker
        self.fallback_max_steps = fallback_max_steps
        self.fallback_deadline_s = fallback_deadline_s
        self.clock = clock
        # control-plane + executor telemetry (DESIGN.md §12): one registry
        # shared with the serving layers; callers may pass theirs in
        self.metrics = metrics if metrics is not None else MetricRegistry()
        self._m_register_seconds = self.metrics.counter(
            "registry_register_seconds_total",
            "wall seconds inside register() (compile + tape + verify + link)",
        )
        self._m_relink_seconds = self.metrics.counter(
            "registry_relink_seconds_total",
            "wall seconds re-cutting the linked tape (control plane)",
        )
        self._m_relinks = self.metrics.counter(
            "registry_relinks_total", "linked-tape re-cuts (membership changes)"
        )
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._swap_failures: Dict[str, str] = {}
        # endpoint -> subsumption verdict of its most recent hot-swap
        # (equivalent / widened / narrowed / incomparable / unknown)
        self._swap_verdicts: Dict[str, str] = {}
        self._entries: Dict[str, Dict[int, SchemaEntry]] = {}
        self._active: Dict[str, int] = {}  # endpoint -> serving version
        self._order: List[str] = []  # registration order = member order
        # version numbers are monotonic per endpoint FOREVER (they survive
        # full eviction): the linked-state signature relies on
        # (endpoint, version) pairs never being reused
        self._next_version: Dict[str, int] = {}
        self._segments: Dict[Tuple[str, int], TapeSegment] = {}
        self._generation = 0
        # lazily (re)built linked state, keyed by the tuple of batchable
        # (endpoint, serving-version) members so no-op generation bumps
        # (evicting a non-serving version, registering a sequential-only
        # schema) never discard the jitted serving validator
        self._linked_generation = -1
        self._linked_signature: Optional[Tuple[Tuple[str, int], ...]] = None
        self._linked: Optional[LinkedTape] = None
        self._linked_validator: Optional[BatchValidator] = None
        self._member_index: Dict[str, int] = {}
        # link groups (DESIGN.md §14): the serving partition.  Eagerly
        # re-cut at registration/eviction (the serving path never links);
        # cached per (endpoint, version) membership tuple so no-op
        # generation bumps keep every group's jitted validator alive.
        # ``link_grouping=False`` pins the legacy single-group layout
        # (one global tape) -- the differential-identity reference.
        self.link_grouping = link_grouping
        self._groups: List[LinkGroup] = []
        self._group_cache: Dict[Tuple[Tuple[str, int], ...], LinkGroup] = {}
        self._member_group: Dict[str, int] = {}
        self._groups_generation = -1
        # cumulative per-group launch-fallback causes (mirrors the
        # registry_group_fallbacks_total counter family)
        self._group_fallbacks: Dict[str, Dict[str, int]] = {}

    # -- registration ---------------------------------------------------------

    def register(
        self, endpoint: str, schema: Any, *, verify: str = "fast"
    ) -> SchemaEntry:
        """Compile + cache ``schema`` as the next version of ``endpoint``.

        All control-plane cost lands here, at registration time: schema
        compilation AND the linked-tape re-cut (pure numpy concatenation
        over cached per-version segments).  The serving path never
        re-links; the only residual first-call cost there is the jit
        trace per new batch shape, which any executor (single-tape
        included) pays.  Re-registering the currently-serving schema
        verbatim is a no-op returning the existing entry (no version
        bump, no re-link, no jit discard).

        Hot-swap safety: the new version is built, smoke-verified
        (``verify="fast"``: differential spot-check of the compiled
        validator against the naive interpreter on a synthetic probe
        corpus), and trial-segmented *before* any registry state
        mutates.  Any failure raises :class:`RegistrationError`, records
        the reason (:meth:`swap_failures`), and leaves the prior version
        serving -- a bad schema version never reaches traffic.
        ``verify="off"`` skips the differential probes.

        With ``analysis=True`` (default) the schema-algebra pipeline
        (DESIGN.md §15) runs first: the schema is normalized and proven-
        unsat branches are pruned before lowering, and the candidate is
        compared against the serving version.  A swap *proven*
        equivalent is a metadata-only no-op -- the serving entry, its
        linked segments and every jitted validator stay untouched
        (generation does not move); a swap proven to widen the accepted
        set proceeds but emits :class:`WidenedSwapWarning` and bumps
        ``registry_swap_widened_total``.
        """
        if endpoint in self._active:
            current = self.get(endpoint)
            if current.schema == schema:
                return current
        # snapshot: entries own their schema by value, so callers mutating
        # the dict they registered cannot corrupt (or no-op-skip) later
        # registrations against the served version
        schema = copy.deepcopy(schema)
        t_reg = time.perf_counter()
        # -- ahead-of-time schema algebra (DESIGN.md §15) ---------------------
        report: Optional[AnalysisReport] = None
        lowered = schema
        if self.analysis:
            with _phase("analyze"):
                report = analyze_schema(schema, verify=(verify != "off"))
            lowered = report.normalized
        # -- subsumption proof vs the serving version -------------------------
        verdict = ""
        if report is not None and endpoint in self._active:
            prev = self.get(endpoint)
            result = subsume_compare(
                prev.canonical if prev.canonical is not None else prev.schema,
                lowered,
                old_hash=prev.canonical_hash or None,
                new_hash=report.canonical_hash or None,
            )
            verdict = result.verdict
            self._swap_verdicts[endpoint] = verdict
            if verdict == "equivalent":
                # metadata-only no-op: the candidate is proven to accept
                # exactly the serving version's instance set, so the
                # serving entry, its cached segments, every link group
                # and every jit trace stay alive.  No version bump, no
                # generation move, no relink.
                prev.stats.subsumption = verdict
                self.metrics.counter(
                    "registry_swap_total",
                    "registration swaps by result",
                    result="equivalent_noop",
                ).inc()
                self._m_register_seconds.inc(time.perf_counter() - t_reg)
                return prev
            if verdict == "widened":
                self.metrics.counter(
                    "registry_swap_widened_total",
                    "hot-swaps proven to accept strictly more instances",
                    endpoint=endpoint,
                ).inc()
                warnings.warn(
                    f"endpoint {endpoint!r}: replacement schema is proven "
                    f"to accept strictly more instances than serving "
                    f"version {prev.version} (witness: "
                    f"{result.witnesses[:1]!r}); swap proceeds",
                    WidenedSwapWarning,
                    stacklevel=2,
                )
        # -- build (no state mutated on failure) ------------------------------
        try:
            t0 = time.perf_counter()
            compiled = compile_schema(lowered)
            validator = Validator(compiled, engine=self.engine)
            t_compile = time.perf_counter() - t0
            t0 = time.perf_counter()
            unroll = self._resolve_unroll_depth(compiled)
            tape, reason = try_build_tape(compiled, unroll_depth=unroll)
            t_tape = time.perf_counter() - t0
        except Exception as exc:
            raise self._swap_failed(endpoint, f"build: {type(exc).__name__}: {exc}")
        # -- smoke-verify before swap (Type Safety w/ JSON Subschema spirit) --
        if verify != "off":
            mismatch = self._smoke_verify(schema, validator)
            if mismatch:
                raise self._swap_failed(endpoint, f"verify: {mismatch}")
        # -- trial link: segment the tape before committing membership --------
        segment: Optional[TapeSegment] = None
        if tape is not None:
            try:
                fault_point("link", endpoint)
                segment = segment_tape(tape)
            except Exception as exc:
                raise self._swap_failed(endpoint, f"link: {type(exc).__name__}: {exc}")
        # -- commit: atomically swap the serving version ----------------------
        stats = SchemaStats(
            compile_seconds=t_compile,
            tape_seconds=t_tape,
            instruction_count=compiled.instruction_count(),
            batchable=tape is not None,
            fallback_reason=reason,
        )
        if tape is not None:
            stats.n_locations = tape.n_locations
            stats.n_props = tape.n_props
            stats.n_assertions = tape.n_assertions
            stats.a_hat = tape.max_rows_per_loc
            stats.k = tape.max_hash_run
            stats.horizon = tape.max_loc_depth + 1
            stats.unroll_depth = tape.unroll_depth
            stats.n_frontier = tape.n_frontier
            stats.n_circuits = tape.n_circuits
            stats.circ_depth = tape.max_circ_depth
        if report is not None:
            stats.analysis_seconds = report.seconds
            stats.normalized = report.changed
            stats.pruned_branches = report.pruned_branches
            stats.folded_assertions = (
                report.folded_assertions
                + report.tightened_bounds
                + report.removed_noops
            )
            stats.analysis_failure = report.failure or ""
            # structural dedup posture: how many of this schema's
            # canonical subgraphs already occur in another serving member
            if report.subgraph_hashes:
                mine = set(report.subgraph_hashes)
                others: set = set()
                for ep in self._order:
                    if ep == endpoint:
                        continue
                    other = self.get(ep)
                    if other.analysis is not None:
                        others.update(other.analysis.subgraph_hashes)
                report.dedup_subgraphs = len(mine & others)
                stats.dedup_subgraphs = report.dedup_subgraphs
        stats.subsumption = verdict
        versions = self._entries.setdefault(endpoint, {})
        version = self._next_version.get(endpoint, 0) + 1
        self._next_version[endpoint] = version
        entry = SchemaEntry(
            endpoint=endpoint,
            version=version,
            schema=schema,
            compiled=compiled,
            validator=validator,
            tape=tape,
            stats=stats,
            canonical=lowered,
            canonical_hash=report.canonical_hash if report is not None else "",
            analysis=report,
        )
        versions[version] = entry
        self._active[endpoint] = version
        if endpoint not in self._order:
            self._order.append(endpoint)
        if segment is not None:
            self._segments[(endpoint, version)] = segment
        self._swap_failures.pop(endpoint, None)
        self._generation += 1
        self._relink_groups()  # eager: keep re-link cost off the serving path
        self._m_register_seconds.inc(time.perf_counter() - t_reg)
        self.metrics.counter(
            "registry_swap_total", "registration swaps by result", result="ok"
        ).inc()
        return entry

    def _swap_failed(self, endpoint: str, reason: str) -> RegistrationError:
        self.metrics.counter(
            "registry_swap_total", "registration swaps by result", result="failed"
        ).inc()
        self._swap_failures[endpoint] = reason
        serving = ""
        if endpoint in self._active:
            serving = f"; version {self._active[endpoint]} keeps serving"
        return RegistrationError(f"endpoint {endpoint!r}: {reason}{serving}")

    def swap_failures(self) -> Dict[str, str]:
        """endpoint -> reason of its most recent *failed* registration
        (cleared by the next successful swap)."""
        return dict(self._swap_failures)

    def swap_verdicts(self) -> Dict[str, str]:
        """endpoint -> subsumption verdict of the most recent hot-swap
        attempt against its then-serving version (``equivalent`` /
        ``widened`` / ``narrowed`` / ``incomparable`` / ``unknown``).
        First registrations have no verdict."""
        return dict(self._swap_verdicts)

    def _resolve_unroll_depth(self, compiled: CompiledSchema) -> int:
        """Per-schema $ref-unroll budget (DESIGN.md §15).

        Explicit constructor ``unroll_depth`` pins every registration;
        otherwise the ``REPRO_UNROLL_DEPTH`` env var wins, and failing
        that the analyzer sizes the depth from the schema's recursion
        cycle shape under the unroll node budget.
        """
        if self.unroll_depth is not None:
            return self.unroll_depth
        env = os.environ.get("REPRO_UNROLL_DEPTH", "")
        if env:
            try:
                return max(1, int(env))
            except ValueError:
                pass
        return recommend_unroll_depth(compiled)

    @staticmethod
    def _synth_probes(schema: Any) -> List[Any]:
        """Small synthetic corpus for differential smoke-verification."""
        probes: List[Any] = [None, True, 0, 1.5, "x", [], {}]
        if isinstance(schema, dict):
            doc: Dict[str, Any] = {}
            props = schema.get("properties")
            props = props if isinstance(props, dict) else {}
            required = schema.get("required")
            required = required if isinstance(required, list) else []
            by_type = {
                "string": "x",
                "number": 1,
                "integer": 1,
                "boolean": True,
                "array": [],
                "object": {},
                "null": None,
            }
            for name in list(props)[:8] + [k for k in required if isinstance(k, str)]:
                sub = props.get(name)
                t = sub.get("type") if isinstance(sub, dict) else None
                if isinstance(t, list) and t:
                    t = t[0]
                doc[name] = by_type.get(t, "x")
            probes.append(doc)
            probes.append({**doc, "unknown_member_xx": 1})
        return probes

    def _smoke_verify(self, schema: Any, validator: Validator) -> str:
        """Differential spot-check vs the naive interpreter; '' = agree.

        A probe that raises in *both* engines is skipped (outside the
        supported envelope either way); raising in exactly one, or a
        verdict mismatch, fails the swap.
        """
        try:
            naive = NaiveValidator(schema)
        except Exception:
            return ""  # naive oracle unavailable: nothing to differ against
        for probe in self._synth_probes(schema):
            got = want = None
            got_exc = want_exc = None
            try:
                got = validator.is_valid(probe)
            except Exception as exc:
                got_exc = exc
            try:
                want = naive.is_valid(probe)
            except Exception as exc:
                want_exc = exc
            if got_exc is not None and want_exc is not None:
                continue
            if got_exc is not None or want_exc is not None:
                exc = got_exc if got_exc is not None else want_exc
                which = "compiled" if got_exc is not None else "naive"
                return (
                    f"probe {probe!r}: {which} engine raised "
                    f"{type(exc).__name__}: {exc}"
                )
            if bool(got) != bool(want):
                return f"probe {probe!r}: compiled={got} naive={want}"
        return ""

    def get(self, endpoint: str, version: Optional[int] = None) -> SchemaEntry:
        """The serving (or a pinned historical) entry for ``endpoint``."""
        if endpoint not in self._active:
            raise KeyError(f"endpoint {endpoint!r} not registered")
        v = self._active[endpoint] if version is None else version
        try:
            return self._entries[endpoint][v]
        except KeyError:
            raise KeyError(f"endpoint {endpoint!r} has no version {v}") from None

    def evict(self, endpoint: str, version: Optional[int] = None) -> None:
        """Drop one version (or the whole endpoint when ``version=None``).

        Evicting the serving version rolls the endpoint back to its
        newest remaining version.
        """
        if endpoint not in self._entries:
            raise KeyError(f"endpoint {endpoint!r} not registered")
        versions = self._entries[endpoint]
        doomed = list(versions) if version is None else [version]
        for v in doomed:
            if v not in versions:
                raise KeyError(f"endpoint {endpoint!r} has no version {v}")
            del versions[v]
            self._segments.pop((endpoint, v), None)
        if versions:
            if self._active[endpoint] not in versions:
                self._active[endpoint] = max(versions)
        else:
            del self._entries[endpoint]
            del self._active[endpoint]
            self._order.remove(endpoint)
        self._generation += 1
        self._relink_groups()  # eager, and a no-op unless membership changed

    def endpoints(self) -> List[str]:
        return list(self._order)

    def __contains__(self, endpoint: str) -> bool:
        """O(1) membership test (request-critical path friendly)."""
        return endpoint in self._active

    def versions(self, endpoint: str) -> List[int]:
        return sorted(self._entries.get(endpoint, ()))

    def fallback_reasons(self) -> Dict[str, str]:
        """endpoint -> ``try_build_tape`` failure reason, for every
        serving entry outside the structural subset.

        This is the *real* per-endpoint reason string (e.g. ``"instruction
        LOOP_KEYS not batchable"``), previously recorded in
        :class:`SchemaStats` but dropped on the serving/stats path --
        ``ServeEngine`` and ``AdmissionController`` surface it.

        Compile-time reasons are endpoint-scoped by construction.
        *Runtime* launch fallbacks (oversize / unroll_overflow /
        undecided) are attributed to the link group whose launch
        produced them -- see :meth:`group_fallbacks` and
        ``AdmitCounts.per_group`` -- not to a single global tape.
        """
        return {
            endpoint: self.get(endpoint).stats.fallback_reason
            for endpoint in self._order
            if not self.get(endpoint).stats.batchable
        }

    @property
    def generation(self) -> int:
        return self._generation

    # -- link groups (DESIGN.md §14) ------------------------------------------

    def _ensure_groups(self) -> None:
        if self._groups_generation != self._generation:
            self._relink_groups()

    def _relink_groups(self) -> None:
        """Partition batchable serving members into link groups and
        (re)cut one linked tape per group.

        The partition keys on :func:`~repro.registry.linker
        .group_signature` -- power-of-two classes of (Â, M̂, horizon) --
        an equivalence relation, so the result is deterministic and
        independent of registration order.  Group state is cached by the
        group's (endpoint, serving-version) tuple: membership-preserving
        generation bumps keep every untouched group's jitted validator
        alive, and a hot-swap re-links only the swapped member's group.
        """
        grouped: Dict[Tuple, List[str]] = {}
        for endpoint in self._order:
            entry = self.get(endpoint)
            if entry.tape is None:
                continue
            key = (endpoint, entry.version)
            if key not in self._segments:
                self._segments[key] = segment_tape(entry.tape)
            gk = group_signature(entry.tape) if self.link_grouping else ("all",)
            grouped.setdefault(gk, []).append(endpoint)
        new_groups: List[LinkGroup] = []
        new_cache: Dict[Tuple[Tuple[str, int], ...], LinkGroup] = {}
        for gk, members in grouped.items():
            label = signature_label(gk) if self.link_grouping else "all"
            signature = tuple((m, self._active[m]) for m in members)
            g = self._group_cache.get(signature)
            if g is None:
                # structural dedup (DESIGN.md §15): a member whose
                # canonical hash matches an earlier member in the group
                # shares that member's linked segment instead of adding
                # a bit-identical copy -- the group tape carries one
                # physical segment per distinct canonical schema and
                # ``member_index`` routes every endpoint to its slot
                reps: List[str] = []
                rep_slot: Dict[str, int] = {}
                member_index: Dict[str, int] = {}
                for m in members:
                    h = self.get(m).canonical_hash if self.dedup_links else ""
                    if h and h in rep_slot:
                        member_index[m] = rep_slot[h]
                        continue
                    slot = len(reps)
                    reps.append(m)
                    if h:
                        rep_slot[h] = slot
                    member_index[m] = slot
                t0 = time.perf_counter()
                with _span(
                    "registry.relink", members=len(reps), group=label
                ):
                    tape = link_tapes(
                        segments=[
                            self._segments[(m, self._active[m])]
                            for m in reps
                        ],
                        names=reps,
                    )
                    validator = BatchValidator(
                        tape,
                        max_depth=self.max_depth,
                        use_pallas=self.use_pallas,
                        layout=self.layout,
                        metrics=self.metrics,
                    )
                g = LinkGroup(
                    label=label,
                    key=gk,
                    members=tuple(members),
                    signature=signature,
                    tape=tape,
                    validator=validator,
                    member_index=member_index,
                    linked_members=tuple(reps),
                )
                self._m_relinks.inc()
                self._m_relink_seconds.inc(time.perf_counter() - t0)
            new_cache[signature] = g
            new_groups.append(g)
        self._groups = new_groups
        self._group_cache = new_cache
        self._member_group = {
            m: gi for gi, g in enumerate(new_groups) for m in g.members
        }
        self._groups_generation = self._generation
        for g in new_groups:
            self.metrics.gauge(
                "registry_group_members",
                "batchable members per link group",
                group=g.label,
            ).set(len(g.members))

    def groups(self) -> List[LinkGroup]:
        """The current link-group partition (registration order)."""
        self._ensure_groups()
        return list(self._groups)

    def group_of(self, endpoint: str) -> Optional[LinkGroup]:
        """The link group serving ``endpoint`` (None = sequential-only)."""
        self._ensure_groups()
        gi = self._member_group.get(endpoint)
        return None if gi is None else self._groups[gi]

    def group_stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-group window facts: the §8 inflation ledger.

        ``a_hat``/``m_hat``/``horizon`` are the *group-local* linked
        maxima -- what every member in the group actually pays per
        launch -- next to the pow2 ``signature_class`` ceilings the
        partition keyed on.
        """
        self._ensure_groups()
        out: Dict[str, Dict[str, Any]] = {}
        for g in self._groups:
            out[g.label] = {
                "members": list(g.members),
                "n_members": len(g.members),
                "linked_members": list(g.linked_members),
                "n_linked": len(g.linked_members),
                "a_hat": int(g.tape.max_rows_per_loc),
                "m_hat": int(g.tape.max_member_props),
                "k": int(g.tape.max_hash_run),
                "horizon": int(g.tape.max_loc_depth) + 1,
                "signature_class": (
                    {"a_hat": g.key[0], "m_hat": g.key[1], "horizon": g.key[2]}
                    if self.link_grouping
                    else {}
                ),
                "fallbacks": dict(self._group_fallbacks.get(g.label, {})),
            }
        return out

    def warm_groups(
        self, batches: Sequence[int], *, max_nodes: int = 256
    ) -> int:
        """Pre-trace every link group's launch at the given batch sizes
        (power-of-two bucketed, matching admission padding); returns the
        number of new jit traces.  Streaming schedulers call this at
        attach time so deadline-bounded drains never pay a trace."""
        from ..data.doc_table import encode_batch

        self._ensure_groups()
        traced = 0
        for g in self._groups:
            for b in batches:
                bucket = 1 << (int(b) - 1).bit_length() if b > 1 else 1
                keys = [("__warm__", j) for j in range(bucket)]
                table = encode_batch(
                    [None] * bucket,
                    max_nodes=max_nodes,
                    isolate=True,
                    keys=keys,
                )
                traced += int(
                    g.validator.warm(table, np.zeros(bucket, np.int32))
                )
        return traced

    def group_fallbacks(self) -> Dict[str, Dict[str, int]]:
        """group label -> cumulative launch-fallback causes
        (oversize / unroll_overflow / undecided / error_isolated),
        attributed to the group whose launch produced them."""
        return {k: dict(v) for k, v in self._group_fallbacks.items()}

    def _count_group_fallback(self, label: str, reason: str) -> None:
        per = self._group_fallbacks.setdefault(label, {})
        per[reason] = per.get(reason, 0) + 1
        self.metrics.counter(
            "registry_group_fallbacks_total",
            "linked-launch fallback causes per link group",
            group=label,
            reason=reason,
        ).inc()

    # -- linked-tape state (global, legacy single-tape view) ------------------

    def _relink(self) -> None:
        """Re-cut the *global* linked tape from cached per-version segments.

        The serving path launches per link group; this all-members tape
        is kept for the mixed-batch compatibility API
        (:meth:`validate_mixed` / :meth:`schema_ids` /
        :meth:`batch_validator`) and is (re)built lazily on access --
        callers that never touch it never pay for it.
        """
        members: List[str] = []
        segments: List[TapeSegment] = []
        for endpoint in self._order:
            entry = self.get(endpoint)
            if entry.tape is None:
                continue
            key = (endpoint, entry.version)
            seg = self._segments.get(key)
            if seg is None:
                seg = self._segments[key] = segment_tape(entry.tape)
            members.append(endpoint)
            segments.append(seg)
        signature = tuple(
            (m, self._active[m]) for m in members
        )
        if signature == self._linked_signature:
            # membership unchanged: keep the jitted validator alive
            self._linked_generation = self._generation
            return
        t0 = time.perf_counter()
        with _span("registry.relink", members=len(members)):
            if members:
                self._linked = link_tapes(segments=segments, names=members)
                self._linked_validator = BatchValidator(
                    self._linked,
                    max_depth=self.max_depth,
                    use_pallas=self.use_pallas,
                    layout=self.layout,
                    metrics=self.metrics,
                )
            else:
                self._linked = None
                self._linked_validator = None
        self._member_index = {m: i for i, m in enumerate(members)}
        self._linked_signature = signature
        self._linked_generation = self._generation
        self._m_relinks.inc()
        self._m_relink_seconds.inc(time.perf_counter() - t0)

    def linked_tape(self) -> Optional[LinkedTape]:
        """The linked tape over all batchable serving versions (or None)."""
        if self._linked_generation != self._generation:
            self._relink()
        return self._linked

    def batch_validator(self) -> Optional[BatchValidator]:
        """Batched executor over the current linked tape (or None)."""
        if self._linked_generation != self._generation:
            self._relink()
        return self._linked_validator

    def schema_ids(self, endpoints: Sequence[str]) -> np.ndarray:
        """Member indices into the linked tape; -1 = sequential-only."""
        if self._linked_generation != self._generation:
            self._relink()
        return np.array(
            [self._member_index.get(e, -1) for e in endpoints], np.int32
        )

    # -- multi-tenant validation ---------------------------------------------

    def validate_mixed(
        self,
        table,
        endpoints: Sequence[str],
        *,
        schema_ids: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """One batched launch over a heterogeneous (mixed-schema) batch.

        ``table`` is an encoded :class:`~repro.data.doc_table.TokenTable`
        whose row b belongs to ``endpoints[b]``.  Returns ``(valid,
        decided)``; rows of unbatchable endpoints come back
        ``decided=False`` and must be routed to that endpoint's
        sequential validator (``self.get(endpoint).validator``).
        """
        B = table.batch
        if len(endpoints) != B:
            raise ValueError(f"{len(endpoints)} endpoints for batch of {B}")
        for e in set(endpoints):
            self.get(e)  # raises KeyError on unknown endpoints
        bv = self.batch_validator()
        if bv is None:
            return np.zeros(B, bool), np.zeros(B, bool)
        ids = self.schema_ids(endpoints) if schema_ids is None else schema_ids
        batchable = ids >= 0
        valid, decided = bv.validate(table, np.where(batchable, ids, 0))
        return valid, decided & batchable

    def admit_mixed(
        self, docs: Sequence[Any], endpoints: Sequence[str], *, max_nodes: int = 256
    ) -> Tuple[List[bool], "AdmitCounts"]:
        """Boolean-verdict compatibility wrapper over :meth:`admit_mixed_ex`.

        Every non-ADMITTED containment disposition (guard reject,
        isolated error, timeout, suspended fallback) maps to ``False``.
        """
        verdicts, counts = self.admit_mixed_ex(docs, endpoints, max_nodes=max_nodes)
        return [v.valid for v in verdicts], counts

    def admit_mixed_ex(
        self,
        docs: Sequence[Any],
        endpoints: Sequence[str],
        *,
        max_nodes: int = 256,
        keys: Optional[Sequence[Any]] = None,
        explain: bool = False,
    ) -> Tuple[List[Verdict], "AdmitCounts"]:
        """Full mixed-stream admission: one linked launch + routed fallback.

        The fault-contained serving path (DESIGN.md §11).  Per row:
        admission resource guards run *before* any encode work
        (REJECTED_GUARD); linked-tape-member rows encode with
        per-document isolation and launch through the bisecting isolator
        (poison rows -> ERROR_ISOLATED, everything else bit-identical to
        a fault-free run); undecided/unbatchable rows route to that
        endpoint's *bounded* sequential fallback behind its circuit
        breaker (TIMED_OUT past the budget; UNDECIDED_FALLBACK while the
        breaker is open).  Exactly one outcome per row, so
        ``len(docs) == sum of all outcome counters``.

        ``keys`` names each row at the fault-injection seams (defaults
        to the row index).  Returns per-row :class:`Verdict`s plus
        counters; the serving engine and the pipeline admission
        controller share this path.

        ``explain=True`` opts into first-failure attribution (DESIGN.md
        §12): INVALID verdicts carry a ``FailureSite`` on ``.site`` and
        a rendered reason.  Batched rows pay one extra (separate) explain
        launch over the already-encoded table; sequential rows re-run
        the diagnostic interpreter.  ``explain=False`` traffic pays
        nothing -- the fast path is unchanged.
        """
        if len(endpoints) != len(docs):
            raise ValueError(f"{len(endpoints)} endpoints for {len(docs)} docs")
        for e in set(endpoints):
            self.get(e)
        row_keys = list(keys) if keys is not None else list(range(len(docs)))
        if len(row_keys) != len(docs):
            raise ValueError(f"{len(row_keys)} keys for {len(docs)} docs")
        verdicts: List[Optional[Verdict]] = [None] * len(docs)
        counts = AdmitCounts()
        with _phase("admit.guard"), _span("registry.guard", batch=len(docs)):
            for i, doc in enumerate(docs):
                why = resource_guard(doc, self.guard)
                if why:
                    verdicts[i] = Verdict(
                        ValidationOutcome.REJECTED_GUARD, False, why
                    )
                    counts.rejected_guard += 1
        self._ensure_groups()
        # one launch per link group with members aboard (DESIGN.md §14):
        # each group pays its own group-local Â/M̂/horizon windows
        by_group: Dict[int, List[int]] = {}
        for i in range(len(docs)):
            if verdicts[i] is None:
                gi = self._member_group.get(endpoints[i])
                if gi is not None:
                    by_group.setdefault(gi, []).append(i)
        for gi in sorted(by_group):
            self._admit_group(
                self._groups[gi],
                by_group[gi],
                docs,
                endpoints,
                row_keys,
                verdicts,
                counts,
                max_nodes=max_nodes,
                explain=explain,
            )
        with _phase("admit.verdicts"):
            for i in range(len(docs)):
                if verdicts[i] is None:
                    v = self._bounded_fallback(
                        endpoints[i], docs[i], row_keys[i], explain=explain
                    )
                    verdicts[i] = v
                    if v.outcome in (
                        ValidationOutcome.ADMITTED,
                        ValidationOutcome.INVALID,
                    ):
                        counts.fallback_validated += 1
                    elif v.outcome is ValidationOutcome.TIMED_OUT:
                        counts.timed_out += 1
                    elif v.outcome is ValidationOutcome.UNDECIDED_FALLBACK:
                        counts.breaker_open += 1
                    else:
                        counts.error_isolated += 1
        return verdicts, counts  # type: ignore[return-value]

    def _admit_group(
        self,
        g: LinkGroup,
        rows: List[int],
        docs: Sequence[Any],
        endpoints: Sequence[str],
        row_keys: List[Any],
        verdicts: List[Optional[Verdict]],
        counts: "AdmitCounts",
        *,
        max_nodes: int,
        explain: bool,
    ) -> None:
        """One isolated launch of ``rows`` over ``g``'s linked tape.

        Verdict semantics are identical to the legacy single-tape fast
        path (differentially pinned bit-identical by the tests); the only
        change is *which* linked tape the rows ride, plus per-group
        attribution of launch-fallback causes.
        """
        from ..data.doc_table import encode_batch

        per = counts.group(g.label)
        # pad the batch dimension to a power-of-two bucket: the
        # executor re-traces per batch shape, and len(rows) is
        # traffic-controlled -- bucketing caps compilations at
        # log2(max burst) instead of one per distinct size
        bucket = 1 << (len(rows) - 1).bit_length() if len(rows) > 1 else 1
        pad = bucket - len(rows)
        fast_keys = [row_keys[i] for i in rows] + [
            ("__pad__", j) for j in range(pad)
        ]
        with _phase("admit.encode"), _span(
            "registry.encode", batch=bucket, group=g.label
        ):
            table = encode_batch(
                [docs[i] for i in rows] + [None] * pad,
                max_nodes=max_nodes,
                isolate=True,
                keys=fast_keys,
            )
        ids = np.array(
            [g.member_index[endpoints[i]] for i in rows] + [0] * pad,
            np.int32,
        )
        # admit.launch's exclusive time is the bisect/bookkeeping
        # overhead around the executor.compile/execute children
        with _phase("admit.launch"):
            valid, decided, frontier, errors = g.validator.validate_isolated(
                table, ids, keys=fast_keys
            )
        sites: List[Optional[FailureSite]] = []
        if explain and any(
            decided[j] and not valid[j] and j not in errors
            for j in range(len(rows))
        ):
            # opt-in second launch over the same encoded table: the
            # argmax over per-row failures (core/explain.py); rows we
            # don't attribute below are simply ignored
            try:
                with _phase("admit.explain"):
                    sites = g.validator.explain_batch(
                        table,
                        ids,
                        docs=[docs[i] for i in rows] + [None] * pad,
                    )
            except Exception:
                sites = []  # attribution is best-effort diagnostics
        with _phase("admit.verdicts"):
            for j, i in enumerate(rows):
                if j in errors:
                    verdicts[i] = Verdict(
                        ValidationOutcome.ERROR_ISOLATED,
                        False,
                        errors[j],
                        "batched",
                    )
                    counts.error_isolated += 1
                    per["error_isolated"] += 1
                    self._count_group_fallback(g.label, "error_isolated")
                elif decided[j]:
                    ok = bool(valid[j])
                    site = None if ok or j >= len(sites) else sites[j]
                    verdicts[i] = Verdict(
                        ValidationOutcome.ADMITTED
                        if ok
                        else ValidationOutcome.INVALID,
                        ok,
                        ""
                        if ok
                        else (
                            site.render()
                            if site is not None
                            else "schema validation failed"
                        ),
                        "batched",
                        site,
                    )
                    counts.batch_validated += 1
                    per["batch_validated"] += 1
                elif not table.ok[j]:
                    counts.oversize += 1  # encoder node/depth budget
                    per["oversize"] += 1
                    self._count_group_fallback(g.label, "oversize")
                elif frontier[j]:
                    counts.unroll_overflow += 1  # $ref-unroll budget
                    per["unroll_overflow"] += 1
                    self._count_group_fallback(g.label, "unroll_overflow")
                else:
                    counts.undecided += 1  # executor depth budget
                    per["undecided"] += 1
                    self._count_group_fallback(g.label, "undecided")

    # -- bounded sequential fallback (the second degradation rung) -----------

    _BREAKER_STATES = {"closed": 0, "half_open": 1, "open": 2}

    def breaker(self, endpoint: str) -> CircuitBreaker:
        """The endpoint's fallback circuit breaker (created on first use)."""
        b = self._breakers.get(endpoint)
        if b is None:
            b = self._breakers[endpoint] = CircuitBreaker(
                self.breaker_cfg, clock=self.clock
            )
        return b

    def _breaker_gauge(self, endpoint: str, breaker: CircuitBreaker) -> None:
        self.metrics.gauge(
            "breaker_state",
            "fallback breaker per endpoint (0=closed 1=half_open 2=open)",
            endpoint=endpoint,
        ).set(self._BREAKER_STATES.get(breaker.state, -1))

    def _explain_sequential(self, endpoint: str, doc: Any) -> Optional[FailureSite]:
        """Innermost sequential trace entry as a FailureSite (best-effort)."""
        try:
            ok, trace = self.get(endpoint).validator.explain(doc)
        except Exception:
            return None
        if ok or not trace:
            return None
        path, _instr = trace[0]  # innermost failure first
        return FailureSite(path, keyword_of(path))

    def _bounded_fallback(
        self, endpoint: str, doc: Any, key: Any, *, explain: bool = False
    ) -> Verdict:
        breaker = self.breaker(endpoint)
        if not breaker.allow():
            self._breaker_gauge(endpoint, breaker)
            return Verdict(
                ValidationOutcome.UNDECIDED_FALLBACK,
                False,
                "fallback suspended: circuit open (endpoint degraded)",
            )
        try:
            fault_point("fallback", key)
            budget = ValidationBudget(
                max_steps=self.fallback_max_steps,
                deadline_s=self.fallback_deadline_s,
                clock=self.clock,
            )
            with _phase("fallback.sequential"), _span(
                "registry.fallback", endpoint=endpoint
            ):
                ok = self.get(endpoint).validator.is_valid_bounded(
                    doc, budget=budget
                )
        except (ValidationTimeout, DocumentDepthError) as exc:
            breaker.record_timeout()
            self._breaker_gauge(endpoint, breaker)
            return Verdict(
                ValidationOutcome.TIMED_OUT, False, str(exc), "sequential"
            )
        except Exception as exc:
            # a per-document error, not an endpoint-health signal: the
            # breaker only counts timeouts
            return Verdict(
                ValidationOutcome.ERROR_ISOLATED,
                False,
                f"{type(exc).__name__}: {exc}",
                "sequential",
            )
        breaker.record_success()
        self._breaker_gauge(endpoint, breaker)
        site = None
        if not ok and explain:
            # opt-in diagnostics: re-run the (unbounded) trace interpreter
            # on a document the bounded oracle already completed once
            site = self._explain_sequential(endpoint, doc)
        return Verdict(
            ValidationOutcome.ADMITTED if ok else ValidationOutcome.INVALID,
            ok,
            ""
            if ok
            else (site.render() if site is not None else "schema validation failed"),
            "sequential",
            site,
        )

    def validate_one(
        self, endpoint: str, doc: Any, *, key: Any = None, explain: bool = False
    ) -> Verdict:
        """Single-document admission through the same containment ladder:
        resource guard, then the breaker-gated bounded fallback."""
        self.get(endpoint)  # KeyError on unknown endpoints
        why = resource_guard(doc, self.guard)
        if why:
            return Verdict(ValidationOutcome.REJECTED_GUARD, False, why)
        return self._bounded_fallback(
            endpoint, doc, key if key is not None else endpoint, explain=explain
        )
