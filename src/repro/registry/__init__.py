"""Multi-tenant schema estate: registry + tape linker.

``registry.py`` owns compiled-schema versions per endpoint id;
``linker.py`` relocates and concatenates member location tapes into
linked tapes so a mixed-endpoint batch validates in few batched kernel
launches (DESIGN.md §8).  Members are partitioned into **link groups**
of compatible (Â, M̂, horizon) signature classes (DESIGN.md §14) so one
window-fat member does not inflate every other endpoint's launches.
"""

from .linker import (
    LinkedTape,
    TapeSegment,
    group_signature,
    link_tapes,
    pow2_class,
    segment_tape,
    signature_label,
)
from .registry import (
    AdmitCounts,
    LinkGroup,
    RegistrationError,
    SchemaEntry,
    SchemaRegistry,
    SchemaStats,
)

__all__ = [
    "LinkedTape",
    "TapeSegment",
    "link_tapes",
    "segment_tape",
    "group_signature",
    "signature_label",
    "pow2_class",
    "AdmitCounts",
    "LinkGroup",
    "RegistrationError",
    "SchemaEntry",
    "SchemaRegistry",
    "SchemaStats",
]
