"""Multi-tenant schema estate: registry + tape linker.

``registry.py`` owns compiled-schema versions per endpoint id;
``linker.py`` relocates and concatenates their location tapes into one
linked tape so a mixed-endpoint batch validates in a single batched
kernel launch (DESIGN.md §8).
"""

from .linker import LinkedTape, TapeSegment, link_tapes, segment_tape
from .registry import (
    AdmitCounts,
    RegistrationError,
    SchemaEntry,
    SchemaRegistry,
    SchemaStats,
)

__all__ = [
    "LinkedTape",
    "TapeSegment",
    "link_tapes",
    "segment_tape",
    "AdmitCounts",
    "RegistrationError",
    "SchemaEntry",
    "SchemaRegistry",
    "SchemaStats",
]
