"""Jit'd public wrappers around the Pallas kernels.

Pads inputs to block multiples, dispatches to the Pallas kernel (interpret
mode on CPU, compiled on TPU) or to the pure-jnp reference when
``use_pallas=False``, and strips padding from the result.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import assertion_eval as _ae
from . import hash_match as _hm
from . import ref as _ref

__all__ = ["hash_match", "assertion_eval", "assertion_eval_window"]


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, size: int, axis: int = 0, fill=0):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _round_up(n: int, block: int) -> int:
    return max(block, ((n + block - 1) // block) * block)


def _with_acquired(node_cols: dict) -> dict:
    """Default the acquired-slot bitmask to zeros for callers without one.

    OBJ_HAS_SLOT rows only appear on tapes with logical-applicator
    circuits; plain callers (kernel tests, dense baselines over
    circuit-free tapes) need not thread the column through.
    """
    if "acquired" in node_cols:
        return node_cols
    out = dict(node_cols)
    out["acquired"] = jnp.zeros_like(node_cols["size"])
    return out


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "use_pallas", "interpret")
)
def hash_match(
    q_lanes: jax.Array,
    q_owner: jax.Array,
    t_lanes: jax.Array,
    t_owner: jax.Array,
    *,
    block_n: int = _hm.BLOCK_N,
    block_m: int = _hm.BLOCK_M,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """(N,) int32 minimal matching table row or -1 (see hash_match.py)."""
    if not use_pallas:
        return _ref.hash_match_ref(q_lanes, q_owner, t_lanes, t_owner)
    interpret = _interpret_default() if interpret is None else interpret
    n, m = q_lanes.shape[0], t_lanes.shape[0]
    np_, mp = _round_up(n, block_n), _round_up(m, block_m)
    out = _hm.hash_match_pallas(
        _pad_to(q_lanes, np_),
        # padded queries get owner -1; padded table rows owner -9 -> no match
        _pad_to(q_owner, np_, fill=-1),
        _pad_to(t_lanes, mp),
        _pad_to(t_owner, mp, fill=-9),
        block_n=block_n,
        block_m=block_m,
        interpret=interpret,
    )
    return out[:n]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_a", "use_pallas", "interpret")
)
def assertion_eval(
    node_cols: dict,
    asrt_cols: dict,
    *,
    block_n: int = _ae.BLOCK_N,
    block_a: int = _ae.BLOCK_A,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """(N, A) int8 pass matrix (see assertion_eval.py)."""
    node_cols = _with_acquired(node_cols)
    if not use_pallas:
        return _ref.assertion_eval_ref(node_cols, asrt_cols)
    interpret = _interpret_default() if interpret is None else interpret
    n = node_cols["type"].shape[0]
    a = asrt_cols["op"].shape[0]
    np_, ap = _round_up(n, block_n), _round_up(a, block_a)
    node_pad = {k: _pad_to(v, np_) for k, v in node_cols.items()}
    # padded assertion rows get op -1 -> never selected -> result 0
    asrt_pad = {
        k: _pad_to(v, ap, fill=(-1 if k == "op" else 0)) for k, v in asrt_cols.items()
    }
    out = _ae.assertion_eval_pallas(
        node_pad, asrt_pad, block_n=block_n, block_a=block_a, interpret=interpret
    )
    return out[:n, :a]


@functools.partial(
    jax.jit, static_argnames=("block_n", "use_pallas", "interpret")
)
def assertion_eval_window(
    node_cols: dict,
    w_cols: dict,
    *,
    block_n: int = _ae.BLOCK_N,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """(N, W) int8 pass matrix over pre-gathered CSR windows.

    ``w_cols`` holds per-node windowed operands (op/f0/i0/i1/u0/u1 of
    shape (N, W), hash of shape (N, W, 8)); masked slots must carry op=-1.
    """
    node_cols = _with_acquired(node_cols)
    if not use_pallas:
        return _ref.assertion_eval_window_ref(node_cols, w_cols)
    interpret = _interpret_default() if interpret is None else interpret
    n = node_cols["type"].shape[0]
    w = w_cols["op"].shape[1]
    np_ = _round_up(n, block_n)
    wp = _round_up(w, _ae.WINDOW_ALIGN)
    node_pad = {k: _pad_to(v, np_) for k, v in node_cols.items()}
    # padded slots get op -1 -> never selected -> result 0
    w_pad = {}
    for k, v in w_cols.items():
        v = _pad_to(v, np_, axis=0)
        w_pad[k] = _pad_to(v, wp, axis=1, fill=(-1 if k == "op" else 0))
    out = _ae.assertion_eval_window_pallas(
        node_pad, w_pad, block_n=block_n, interpret=interpret
    )
    return out[:n, :w]
