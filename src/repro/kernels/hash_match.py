"""Pallas TPU kernel: semi-perfect-hash property matching (Blaze §4.1 on TPU).

For every document node, find the schema property-table row whose (key-hash,
owner-location) pair matches the node's (key-hash, parent-location).  This
is the hot inner loop of schema-location assignment in the batched executor
-- the tensorised analogue of the paper's hash-accelerated property lookup.

Shape design: hashes are eight uint32 lanes (no 64-bit vector lanes on TPU).
The kernel tiles the (nodes x table-rows) comparison space into VMEM blocks
of (BN, BM); each of the eight lane-equality comparisons is a rank-2
broadcast (BN, 1) vs (1, BM) on the VPU -- no rank-3 intermediates.  Across
table tiles the minimum matching row index is accumulated in the output
block (revisited output pattern: the N-tile output lives in VMEM across all
M-tiles of the inner grid dimension).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 2**30  # python literal: kept out of traced-constant capture

# Default VMEM tile sizes: 8-sublane x 128-lane aligned.
BLOCK_N = 256
BLOCK_M = 256


def _hash_match_kernel(
    q_lanes_ref,  # (BN, 8)  uint32  query (node key) hash lanes
    q_owner_ref,  # (BN, 1)  int32   query owner (parent location)
    t_lanes_ref,  # (BM, 8)  uint32  table hash lanes
    t_owner_ref,  # (BM, 1)  int32   table owner location
    out_ref,  # (BN, 1)  int32   min matching table row (global index)
    *,
    block_m: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, _BIG, jnp.int32)

    q_owner = q_owner_ref[...]  # (BN, 1)
    t_owner = t_owner_ref[...]  # (BM, 1)
    matched = q_owner == t_owner.reshape(1, -1)  # (BN, BM)
    # eight rank-2 lane comparisons, statically unrolled
    for lane in range(8):
        q = q_lanes_ref[:, lane].reshape(-1, 1)  # (BN, 1)
        t = t_lanes_ref[:, lane].reshape(1, -1)  # (1, BM)
        matched = jnp.logical_and(matched, q == t)
    col = jax.lax.broadcasted_iota(jnp.int32, matched.shape, 1)
    row_idx = jnp.where(matched, col + j * block_m, jnp.int32(_BIG))
    best = jnp.min(row_idx, axis=1, keepdims=True)  # (BN, 1)
    out_ref[...] = jnp.minimum(out_ref[...], best)


def hash_match_pallas(
    q_lanes: jax.Array,  # (N, 8) uint32
    q_owner: jax.Array,  # (N,)   int32
    t_lanes: jax.Array,  # (M, 8) uint32
    t_owner: jax.Array,  # (M,)   int32
    *,
    block_n: int = BLOCK_N,
    block_m: int = BLOCK_M,
    interpret: bool = False,
) -> jax.Array:
    """Returns (N,) int32: minimal matching table row or -1.

    Inputs must be padded to block multiples by the caller (ops.py).
    """
    n, m = q_lanes.shape[0], t_lanes.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    grid = (n // block_n, m // block_m)
    out = pl.pallas_call(
        functools.partial(_hash_match_kernel, block_m=block_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 8), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 8), lambda i, j: (j, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(q_lanes, q_owner.reshape(-1, 1), t_lanes, t_owner.reshape(-1, 1))
    out = out.reshape(-1)
    return jnp.where(out >= _BIG, jnp.int32(-1), out)
