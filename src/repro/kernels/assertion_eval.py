"""Pallas TPU kernels: fused assertion-tape evaluation (dense + windowed).

Two kernels share one branch-free op evaluator (the tensorised version of
the paper's CISC observation, §2.5 -- one *fused* pass over VMEM-resident
columns beats dispatching many small instructions):

* **Dense** (``assertion_eval_pallas``): the historical layout.  Computes
  the full (nodes x assertion-rows) boolean matrix; ownership masking and
  OR-group reduction happen in the surrounding jnp code.  O(N*A) compute
  and memory -- kept as the baseline and for tapes without CSR windows.

* **Windowed** (``assertion_eval_window_pallas``): the CSR fast path.  The
  executor gathers, per node, only the <= A-hat rows of the node's own
  schema location (owner-sorted CSR windows built at compile time in
  ``core.tape``) and hands them over as (nodes x A-hat) operand planes.
  Every op evaluates element-wise on (BN, W) tiles -- O(N*A-hat) instead
  of O(N*A), with no ownership masking needed downstream (a masked slot
  carries op=-1 and evaluates to 0).

Both kernels bake in the paper's *precondition* semantics per op (wrong
type => pass for AND rows, => no-match for OR/const rows).  float32 is
used for numeric bounds on TPU (no native f64); the CPU reference path
keeps f64.  Precision caveat recorded in DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.nodetypes import (
    T_ARR as _T_ARR,
    T_BOOL as _T_BOOL,
    T_NULL as _T_NULL,
    T_NUM as _T_NUM,
    T_OBJ as _T_OBJ,
    T_STR as _T_STR,
)
from ..core.tape import AOP

BLOCK_N = 256
BLOCK_A = 256
# windowed kernel: window (A-hat) padded to a sublane multiple
WINDOW_ALIGN = 8


def _eval_rows(ntype, isint, num, size, acq, pfx0, pfx1, op, f0, i0, i1, u0, u1, hash_eq, out_shape):
    """Branch-free mini-ISA evaluation shared by both kernel layouts.

    Node operands are (BN, 1); assertion operands are either (1, BA)
    (dense) or (BN, W) (windowed); ``hash_eq`` is the 8-lane string-hash
    equality matrix already broadcast to ``out_shape``.  ``acq`` is the
    node's acquired required-slot bitmask (the executor's location
    propagation computes it; OBJ_HAS_SLOT reads one bit).  All candidate
    results are computed unconditionally and combined with a select chain
    on the op code -- the VPU is wide enough that computing all candidates
    costs less than divergent control flow would.
    """
    is_num = ntype == _T_NUM
    is_str = ntype == _T_STR
    is_arr = ntype == _T_ARR
    is_obj = ntype == _T_OBJ

    # TYPE_MASK: node type bit in mask; integers-only via i1
    type_bit = jnp.left_shift(jnp.int32(1), ntype.astype(jnp.int32))
    in_mask = (type_bit & i0) != 0
    ints_ok = jnp.logical_or(
        jnp.logical_or(i1 == 0, jnp.logical_not(is_num)), isint
    )
    r_type = jnp.logical_and(in_mask, ints_ok)

    cmp_num = num
    r_ge = jnp.logical_or(~is_num, cmp_num >= f0)
    r_gt = jnp.logical_or(~is_num, cmp_num > f0)
    r_le = jnp.logical_or(~is_num, cmp_num <= f0)
    r_lt = jnp.logical_or(~is_num, cmp_num < f0)
    # NUM_MULTIPLE: tolerance on the quotient (same formula as the jnp
    # reference, bit-identical) -- exact f32 remainders would reject
    # decimal multiples like 19.99 % 0.01 whose divisor has no exact
    # binary representation.  Capped at 0.25 so large quotients keep
    # rejecting non-multiples (1000001 % 2 stays False).
    q = cmp_num / jnp.where(f0 == 0, jnp.ones_like(f0), f0)
    q_near = jnp.floor(q + 0.5)
    q_tol = jnp.minimum(1e-6 * jnp.maximum(jnp.abs(q), 1.0), 0.25)
    divisible = jnp.logical_and(f0 != 0, jnp.abs(q - q_near) <= q_tol)
    r_mul = jnp.logical_or(~is_num, divisible)

    r_str_min = jnp.logical_or(~is_str, size >= i0)
    r_str_max = jnp.logical_or(~is_str, size <= i0)
    r_arr_min = jnp.logical_or(~is_arr, size >= i0)
    r_arr_max = jnp.logical_or(~is_arr, size <= i0)
    r_obj_min = jnp.logical_or(~is_obj, size >= i0)
    r_obj_max = jnp.logical_or(~is_obj, size <= i0)

    # STR_PREFIX: compare first i0 (<=8) bytes; big-endian packing makes a
    # left-aligned byte mask expressible as integer shifts
    len0 = jnp.minimum(i0, 4)
    len1 = jnp.maximum(i0 - 4, 0)
    shift0 = (jnp.int32(4) - len0) * 8
    shift1 = (jnp.int32(4) - len1) * 8
    full = jnp.uint32(0xFFFFFFFF)
    m0 = jnp.where(len0 == 0, jnp.uint32(0), (full >> shift0.astype(jnp.uint32)) << shift0.astype(jnp.uint32))
    m1 = jnp.where(len1 == 0, jnp.uint32(0), (full >> shift1.astype(jnp.uint32)) << shift1.astype(jnp.uint32))
    pfx_eq = jnp.logical_and((pfx0 & m0) == (u0 & m0), (pfx1 & m1) == (u1 & m1))
    long_enough = size >= i0
    r_prefix = jnp.logical_or(~is_str, jnp.logical_and(pfx_eq, long_enough))

    # STR_EQ / const rows: exact-match semantics (no pass-on-skip)
    r_str_eq = jnp.logical_and(jnp.broadcast_to(is_str, out_shape), hash_eq)
    r_str_eq_pre = jnp.logical_or(jnp.broadcast_to(~is_str, out_shape), hash_eq)
    r_null = jnp.broadcast_to(ntype == _T_NULL, out_shape)
    is_bool = ntype == _T_BOOL
    r_bool = jnp.logical_and(is_bool, num == f0)
    r_num_const = jnp.logical_and(is_num, num == f0)

    # OBJ_HAS_SLOT: the object defines the property wired to slot i0
    # (precondition semantics: non-objects pass)
    slot_bit = (jnp.right_shift(acq, jnp.minimum(jnp.maximum(i0, 0), 31)) & 1) != 0
    r_has_slot = jnp.logical_or(~is_obj, slot_bit)

    candidates = [
        (AOP.TYPE_MASK, r_type),
        (AOP.NUM_GE, r_ge),
        (AOP.NUM_GT, r_gt),
        (AOP.NUM_LE, r_le),
        (AOP.NUM_LT, r_lt),
        (AOP.NUM_MULTIPLE, r_mul),
        (AOP.STR_MINLEN, r_str_min),
        (AOP.STR_MAXLEN, r_str_max),
        (AOP.ARR_MINLEN, r_arr_min),
        (AOP.ARR_MAXLEN, r_arr_max),
        (AOP.OBJ_MINPROPS, r_obj_min),
        (AOP.OBJ_MAXPROPS, r_obj_max),
        (AOP.STR_PREFIX, r_prefix),
        (AOP.STR_EQ, r_str_eq),
        (AOP.CONST_NULL, r_null),
        (AOP.CONST_BOOL, r_bool),
        (AOP.CONST_NUM, r_num_const),
        (AOP.STR_EQ_PRE, r_str_eq_pre),
        (AOP.OBJ_HAS_SLOT, r_has_slot),
    ]
    result = jnp.zeros(out_shape, jnp.bool_)
    for code, value in candidates:
        result = jnp.where(op == code, jnp.broadcast_to(value, out_shape), result)
    return result


# ---------------------------------------------------------------------------
# Dense kernel: (nodes x all-assertion-rows)
# ---------------------------------------------------------------------------


def _assertion_kernel(
    # node columns, (BN, 1) each unless noted
    n_type_ref,
    n_isint_ref,
    n_num_ref,
    n_size_ref,
    n_acq_ref,
    n_strhash_ref,  # (BN, 8) uint32
    n_strpfx_ref,  # (BN, 2) uint32
    # assertion columns, (BA, 1) each unless noted
    a_op_ref,
    a_f0_ref,
    a_i0_ref,
    a_i1_ref,
    a_u0_ref,
    a_u1_ref,
    a_hash_ref,  # (BA, 8) uint32
    out_ref,  # (BN, BA) int8
):
    ntype = n_type_ref[...]  # (BN, 1)
    isint = n_isint_ref[...] != 0
    num = n_num_ref[...]
    size = n_size_ref[...]
    acq = n_acq_ref[...]
    pfx0 = n_strpfx_ref[:, 0].reshape(-1, 1)
    pfx1 = n_strpfx_ref[:, 1].reshape(-1, 1)

    op = a_op_ref[...].reshape(1, -1)  # (1, BA)
    f0 = a_f0_ref[...].reshape(1, -1)
    i0 = a_i0_ref[...].reshape(1, -1)
    i1 = a_i1_ref[...].reshape(1, -1)
    u0 = a_u0_ref[...].reshape(1, -1)
    u1 = a_u1_ref[...].reshape(1, -1)

    # eight rank-2 lane-equality comparisons, statically unrolled
    hash_eq = jnp.ones(out_ref.shape, jnp.bool_)
    for lane in range(8):
        nh = n_strhash_ref[:, lane].reshape(-1, 1)
        ah = a_hash_ref[:, lane].reshape(1, -1)
        hash_eq = jnp.logical_and(hash_eq, nh == ah)

    result = _eval_rows(
        ntype, isint, num, size, acq, pfx0, pfx1, op, f0, i0, i1, u0, u1, hash_eq, out_ref.shape
    )
    out_ref[...] = result.astype(jnp.int8)


def assertion_eval_pallas(
    node_cols: dict,
    asrt_cols: dict,
    *,
    block_n: int = BLOCK_N,
    block_a: int = BLOCK_A,
    interpret: bool = False,
) -> jax.Array:
    """Returns (N, A) int8 pass matrix.  Caller pads to block multiples.

    node_cols: type/is_int/num/size/acquired (N,), str_hash (N,8),
    str_prefix (N,2)
    asrt_cols: op/f0/i0/i1/u0/u1 (A,), hash (A,8)
    """
    n = node_cols["type"].shape[0]
    a = asrt_cols["op"].shape[0]
    assert n % block_n == 0 and a % block_a == 0, (n, a)
    grid = (n // block_n, a // block_a)

    def col2d(x):
        return x.reshape(-1, 1)

    n_spec = pl.BlockSpec((block_n, 1), lambda i, j: (i, 0))
    a_spec = pl.BlockSpec((block_a, 1), lambda i, j: (j, 0))
    out = pl.pallas_call(
        _assertion_kernel,
        grid=grid,
        in_specs=[
            n_spec,
            n_spec,
            n_spec,
            n_spec,
            n_spec,
            pl.BlockSpec((block_n, 8), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 2), lambda i, j: (i, 0)),
            a_spec,
            a_spec,
            a_spec,
            a_spec,
            a_spec,
            a_spec,
            pl.BlockSpec((block_a, 8), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_a), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, a), jnp.int8),
        interpret=interpret,
    )(
        col2d(node_cols["type"].astype(jnp.int32)),
        col2d(node_cols["is_int"].astype(jnp.int32)),
        col2d(node_cols["num"]),
        col2d(node_cols["size"].astype(jnp.int32)),
        col2d(node_cols["acquired"].astype(jnp.int32)),
        node_cols["str_hash"],
        node_cols["str_prefix"],
        col2d(asrt_cols["op"].astype(jnp.int32)),
        col2d(asrt_cols["f0"]),
        col2d(asrt_cols["i0"].astype(jnp.int32)),
        col2d(asrt_cols["i1"].astype(jnp.int32)),
        col2d(asrt_cols["u0"]),
        col2d(asrt_cols["u1"]),
        asrt_cols["hash"],
    )
    return out


# ---------------------------------------------------------------------------
# Windowed kernel: (nodes x A-hat) pre-gathered CSR windows
# ---------------------------------------------------------------------------


def _assertion_window_kernel(
    # node columns, (BN, 1) each unless noted
    n_type_ref,
    n_isint_ref,
    n_num_ref,
    n_size_ref,
    n_acq_ref,
    n_strhash_ref,  # (BN, 8) uint32
    n_strpfx_ref,  # (BN, 2) uint32
    # per-node windowed assertion operands, (BN, W) each unless noted
    a_op_ref,
    a_f0_ref,
    a_i0_ref,
    a_i1_ref,
    a_u0_ref,
    a_u1_ref,
    a_hash_ref,  # (BN, 8*W) uint32, lane-major: columns [lane*W, (lane+1)*W)
    out_ref,  # (BN, W) int8
    *,
    window: int,
):
    ntype = n_type_ref[...]  # (BN, 1)
    isint = n_isint_ref[...] != 0
    num = n_num_ref[...]
    size = n_size_ref[...]
    acq = n_acq_ref[...]
    pfx0 = n_strpfx_ref[:, 0].reshape(-1, 1)
    pfx1 = n_strpfx_ref[:, 1].reshape(-1, 1)

    op = a_op_ref[...]  # (BN, W)
    f0 = a_f0_ref[...]
    i0 = a_i0_ref[...]
    i1 = a_i1_ref[...]
    u0 = a_u0_ref[...]
    u1 = a_u1_ref[...]

    # eight element-wise lane comparisons on static (BN, W) slices
    hash_eq = jnp.ones(out_ref.shape, jnp.bool_)
    for lane in range(8):
        nh = n_strhash_ref[:, lane].reshape(-1, 1)
        ah = a_hash_ref[:, lane * window : (lane + 1) * window]
        hash_eq = jnp.logical_and(hash_eq, nh == ah)

    result = _eval_rows(
        ntype, isint, num, size, acq, pfx0, pfx1, op, f0, i0, i1, u0, u1, hash_eq, out_ref.shape
    )
    out_ref[...] = result.astype(jnp.int8)


def assertion_eval_window_pallas(
    node_cols: dict,
    w_cols: dict,
    *,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    """Returns (N, W) int8 pass matrix for pre-gathered CSR windows.

    node_cols: type/is_int/num/size/acquired (N,), str_hash (N,8),
    str_prefix (N,2)
    w_cols: op/f0/i0/i1/u0/u1 (N, W), hash (N, W, 8).  Masked window slots
    must carry op=-1 (evaluate to 0).  Caller pads N to a block multiple
    and W to a sublane multiple.
    """
    n = node_cols["type"].shape[0]
    w = w_cols["op"].shape[1]
    assert n % block_n == 0 and w % WINDOW_ALIGN == 0, (n, w)
    grid = (n // block_n,)

    def col2d(x):
        return x.reshape(-1, 1)

    # lane-major hash layout keeps every kernel slice static and rank-2
    hash_lm = jnp.transpose(w_cols["hash"], (0, 2, 1)).reshape(n, 8 * w)

    n_spec = pl.BlockSpec((block_n, 1), lambda i: (i, 0))
    w_spec = pl.BlockSpec((block_n, w), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_assertion_window_kernel, window=w),
        grid=grid,
        in_specs=[
            n_spec,
            n_spec,
            n_spec,
            n_spec,
            n_spec,
            pl.BlockSpec((block_n, 8), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
            w_spec,
            w_spec,
            w_spec,
            w_spec,
            w_spec,
            w_spec,
            pl.BlockSpec((block_n, 8 * w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, w), jnp.int8),
        interpret=interpret,
    )(
        col2d(node_cols["type"].astype(jnp.int32)),
        col2d(node_cols["is_int"].astype(jnp.int32)),
        col2d(node_cols["num"]),
        col2d(node_cols["size"].astype(jnp.int32)),
        col2d(node_cols["acquired"].astype(jnp.int32)),
        node_cols["str_hash"],
        node_cols["str_prefix"],
        w_cols["op"].astype(jnp.int32),
        w_cols["f0"],
        w_cols["i0"].astype(jnp.int32),
        w_cols["i1"].astype(jnp.int32),
        w_cols["u0"],
        w_cols["u1"],
        hash_lm,
    )
    return out
