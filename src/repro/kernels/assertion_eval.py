"""Pallas TPU kernel: fused assertion-tape evaluation.

Evaluates every assertion row of a compiled location tape against every
document node in one pass -- the tensorised version of the paper's CISC
observation (§2.5): one *fused* pass over VMEM-resident columns beats
dispatching many small instructions.

The kernel computes a (nodes x assertion-rows) boolean matrix where entry
(n, a) is "row a passes for node n" with the paper's *precondition*
semantics baked in per op (wrong type => pass for AND rows, => no-match for
OR/const rows).  Ownership masking (row applies only at its schema
location) and group reduction happen in the surrounding jnp code -- they
are cheap O(N*A) selects that XLA fuses.

All 17 mini-ISA ops are evaluated branch-free on (BN, BA) tiles and
combined with a select chain on the op code -- the VPU is wide enough that
computing all candidates costs less than divergent control flow would.
float32 is used for numeric bounds on TPU (no native f64); the CPU
reference path keeps f64.  Precision caveat recorded in DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.tape import AOP

BLOCK_N = 256
BLOCK_A = 256

# node type codes (mirrors data.doc_table.TYPE_CODES)
_T_NULL, _T_BOOL, _T_NUM, _T_STR, _T_ARR, _T_OBJ = 1, 2, 3, 4, 5, 6


def _assertion_kernel(
    # node columns, (BN, 1) each unless noted
    n_type_ref,
    n_isint_ref,
    n_num_ref,
    n_size_ref,
    n_strhash_ref,  # (BN, 8) uint32
    n_strpfx_ref,  # (BN, 2) uint32
    # assertion columns, (BA, 1) each unless noted
    a_op_ref,
    a_f0_ref,
    a_i0_ref,
    a_i1_ref,
    a_u0_ref,
    a_u1_ref,
    a_hash_ref,  # (BA, 8) uint32
    out_ref,  # (BN, BA) int8
):
    ntype = n_type_ref[...]  # (BN, 1)
    isint = n_isint_ref[...] != 0
    num = n_num_ref[...]
    size = n_size_ref[...]

    op = a_op_ref[...].reshape(1, -1)  # (1, BA)
    f0 = a_f0_ref[...].reshape(1, -1)
    i0 = a_i0_ref[...].reshape(1, -1)
    i1 = a_i1_ref[...].reshape(1, -1)
    u0 = a_u0_ref[...].reshape(1, -1)
    u1 = a_u1_ref[...].reshape(1, -1)

    is_num = ntype == _T_NUM  # (BN, 1)
    is_str = ntype == _T_STR
    is_arr = ntype == _T_ARR
    is_obj = ntype == _T_OBJ

    # TYPE_MASK: node type bit in mask; integers-only via i1
    type_bit = jnp.left_shift(jnp.int32(1), ntype.astype(jnp.int32))
    in_mask = (type_bit & i0) != 0
    ints_ok = jnp.logical_or(
        jnp.logical_or(i1 == 0, jnp.logical_not(is_num)), isint
    )
    r_type = jnp.logical_and(in_mask, ints_ok)

    cmp_num = num  # (BN, 1) broadcast against (1, BA)
    r_ge = jnp.logical_or(~is_num, cmp_num >= f0)
    r_gt = jnp.logical_or(~is_num, cmp_num > f0)
    r_le = jnp.logical_or(~is_num, cmp_num <= f0)
    r_lt = jnp.logical_or(~is_num, cmp_num < f0)
    q = cmp_num / jnp.where(f0 == 0, jnp.ones_like(f0), f0)
    divisible = jnp.logical_and(f0 != 0, q == jnp.floor(q))
    r_mul = jnp.logical_or(~is_num, divisible)

    r_str_min = jnp.logical_or(~is_str, size >= i0)
    r_str_max = jnp.logical_or(~is_str, size <= i0)
    r_arr_min = jnp.logical_or(~is_arr, size >= i0)
    r_arr_max = jnp.logical_or(~is_arr, size <= i0)
    r_obj_min = jnp.logical_or(~is_obj, size >= i0)
    r_obj_max = jnp.logical_or(~is_obj, size <= i0)

    # STR_PREFIX: compare first i0 (<=8) bytes; big-endian packing makes a
    # left-aligned byte mask expressible as integer shifts
    pfx0 = n_strpfx_ref[:, 0].reshape(-1, 1)
    pfx1 = n_strpfx_ref[:, 1].reshape(-1, 1)
    len0 = jnp.minimum(i0, 4)
    len1 = jnp.maximum(i0 - 4, 0)
    # mask of the first k bytes of a big-endian u32 (k in 0..4)
    shift0 = (jnp.int32(4) - len0) * 8
    shift1 = (jnp.int32(4) - len1) * 8
    full = jnp.uint32(0xFFFFFFFF)
    m0 = jnp.where(len0 == 0, jnp.uint32(0), (full >> shift0.astype(jnp.uint32)) << shift0.astype(jnp.uint32))
    m1 = jnp.where(len1 == 0, jnp.uint32(0), (full >> shift1.astype(jnp.uint32)) << shift1.astype(jnp.uint32))
    pfx_eq = jnp.logical_and((pfx0 & m0) == (u0 & m0), (pfx1 & m1) == (u1 & m1))
    long_enough = size >= i0
    r_prefix = jnp.logical_or(~is_str, jnp.logical_and(pfx_eq, long_enough))

    # STR_EQ / const rows: exact-match semantics (no pass-on-skip)
    str_eq = is_str
    for lane in range(8):
        nh = n_strhash_ref[:, lane].reshape(-1, 1)
        ah = a_hash_ref[:, lane].reshape(1, -1)
        str_eq = jnp.logical_and(str_eq, nh == ah)
    r_str_eq = str_eq
    r_str_eq_pre = jnp.logical_or(jnp.broadcast_to(~is_str, str_eq.shape), str_eq)
    r_null = jnp.broadcast_to(ntype == _T_NULL, str_eq.shape)
    is_bool = ntype == _T_BOOL
    r_bool = jnp.logical_and(is_bool, num == f0)
    r_num_const = jnp.logical_and(is_num, num == f0)

    candidates = [
        (AOP.TYPE_MASK, r_type),
        (AOP.NUM_GE, r_ge),
        (AOP.NUM_GT, r_gt),
        (AOP.NUM_LE, r_le),
        (AOP.NUM_LT, r_lt),
        (AOP.NUM_MULTIPLE, r_mul),
        (AOP.STR_MINLEN, r_str_min),
        (AOP.STR_MAXLEN, r_str_max),
        (AOP.ARR_MINLEN, r_arr_min),
        (AOP.ARR_MAXLEN, r_arr_max),
        (AOP.OBJ_MINPROPS, r_obj_min),
        (AOP.OBJ_MAXPROPS, r_obj_max),
        (AOP.STR_PREFIX, r_prefix),
        (AOP.STR_EQ, r_str_eq),
        (AOP.CONST_NULL, r_null),
        (AOP.CONST_BOOL, r_bool),
        (AOP.CONST_NUM, r_num_const),
        (AOP.STR_EQ_PRE, r_str_eq_pre),
    ]
    result = jnp.zeros(out_ref.shape, jnp.bool_)
    for code, value in candidates:
        result = jnp.where(op == code, jnp.broadcast_to(value, result.shape), result)
    out_ref[...] = result.astype(jnp.int8)


def assertion_eval_pallas(
    node_cols: dict,
    asrt_cols: dict,
    *,
    block_n: int = BLOCK_N,
    block_a: int = BLOCK_A,
    interpret: bool = False,
) -> jax.Array:
    """Returns (N, A) int8 pass matrix.  Caller pads to block multiples.

    node_cols: type/is_int/num/size (N,), str_hash (N,8), str_prefix (N,2)
    asrt_cols: op/f0/i0/i1/u0/u1 (A,), hash (A,8)
    """
    n = node_cols["type"].shape[0]
    a = asrt_cols["op"].shape[0]
    assert n % block_n == 0 and a % block_a == 0, (n, a)
    grid = (n // block_n, a // block_a)

    def col2d(x):
        return x.reshape(-1, 1)

    n_spec = pl.BlockSpec((block_n, 1), lambda i, j: (i, 0))
    a_spec = pl.BlockSpec((block_a, 1), lambda i, j: (j, 0))
    out = pl.pallas_call(
        _assertion_kernel,
        grid=grid,
        in_specs=[
            n_spec,
            n_spec,
            n_spec,
            n_spec,
            pl.BlockSpec((block_n, 8), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 2), lambda i, j: (i, 0)),
            a_spec,
            a_spec,
            a_spec,
            a_spec,
            a_spec,
            a_spec,
            pl.BlockSpec((block_a, 8), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_a), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, a), jnp.int8),
        interpret=interpret,
    )(
        col2d(node_cols["type"].astype(jnp.int32)),
        col2d(node_cols["is_int"].astype(jnp.int32)),
        col2d(node_cols["num"]),
        col2d(node_cols["size"].astype(jnp.int32)),
        node_cols["str_hash"],
        node_cols["str_prefix"],
        col2d(asrt_cols["op"].astype(jnp.int32)),
        col2d(asrt_cols["f0"]),
        col2d(asrt_cols["i0"].astype(jnp.int32)),
        col2d(asrt_cols["i1"].astype(jnp.int32)),
        col2d(asrt_cols["u0"]),
        col2d(asrt_cols["u1"]),
        asrt_cols["hash"],
    )
    return out
