"""Pure-jnp oracles for the Pallas kernels (per-kernel allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.nodetypes import (
    T_ARR as _T_ARR,
    T_BOOL as _T_BOOL,
    T_NULL as _T_NULL,
    T_NUM as _T_NUM,
    T_OBJ as _T_OBJ,
    T_STR as _T_STR,
)
from ..core.tape import AOP


def hash_match_ref(
    q_lanes: jax.Array,  # (N, 8) uint32
    q_owner: jax.Array,  # (N,)   int32
    t_lanes: jax.Array,  # (M, 8) uint32
    t_owner: jax.Array,  # (M,)   int32
) -> jax.Array:
    """(N,) int32: minimal matching table row or -1."""
    lane_eq = q_lanes[:, None, :] == t_lanes[None, :, :]  # (N, M, 8)
    matched = jnp.all(lane_eq, axis=-1) & (q_owner[:, None] == t_owner[None, :])
    big = jnp.int32(2**30)
    idx = jnp.where(matched, jnp.arange(t_lanes.shape[0], dtype=jnp.int32)[None, :], big)
    best = jnp.min(idx, axis=1)
    return jnp.where(best >= big, jnp.int32(-1), best)


def _eval_rows_ref(ntype, isint, num, size, acq, str_pfx0, str_pfx1, op, f0, i0, i1, u0, u1, hash_eq):
    """Mini-ISA row evaluation on already-broadcastable operands.

    ``hash_eq`` carries the 8-lane string-hash equality at the output
    shape; node operands are (N, 1) (``acq`` is the acquired required-slot
    bitmask), assertion operands (1, A) or (N, W).
    """
    out_shape = hash_eq.shape

    is_num = ntype == _T_NUM
    is_str = ntype == _T_STR
    is_arr = ntype == _T_ARR
    is_obj = ntype == _T_OBJ

    type_bit = jnp.left_shift(jnp.int32(1), ntype)
    r_type = ((type_bit & i0) != 0) & ((i1 == 0) | ~is_num | isint)

    r_ge = ~is_num | (num >= f0)
    r_gt = ~is_num | (num > f0)
    r_le = ~is_num | (num <= f0)
    r_lt = ~is_num | (num < f0)
    # NUM_MULTIPLE: decimal divisors (0.01) have no exact binary form, so
    # an exact quotient test would reject true decimal multiples
    # (19.99 % 0.01).  Tolerance on the quotient, relative to its
    # magnitude, matches the sequential executor's decimal-exact
    # semantics to within f32 representation error (DESIGN.md §7).
    # The 0.25 cap keeps the tolerance meaningful for large quotients:
    # without it, 1e-6*|q| crosses 0.5 near |q|~5e5 and every value
    # would pass (1000001 % 2 must stay False).  Past f32's integer
    # range the quotient itself is integral and indistinguishable --
    # the documented §7 precision caveat.
    q = num / jnp.where(f0 == 0, jnp.ones_like(f0), f0)
    q_near = jnp.floor(q + 0.5)
    q_tol = jnp.minimum(1e-6 * jnp.maximum(jnp.abs(q), 1.0), 0.25)
    r_mul = ~is_num | ((f0 != 0) & (jnp.abs(q - q_near) <= q_tol))

    r_str_min = ~is_str | (size >= i0)
    r_str_max = ~is_str | (size <= i0)
    r_arr_min = ~is_arr | (size >= i0)
    r_arr_max = ~is_arr | (size <= i0)
    r_obj_min = ~is_obj | (size >= i0)
    r_obj_max = ~is_obj | (size <= i0)

    len0 = jnp.minimum(i0, 4)
    len1 = jnp.maximum(i0 - 4, 0)
    shift0 = ((4 - len0) * 8).astype(jnp.uint32)
    shift1 = ((4 - len1) * 8).astype(jnp.uint32)
    full = jnp.uint32(0xFFFFFFFF)
    m0 = jnp.where(len0 == 0, jnp.uint32(0), (full >> shift0) << shift0)
    m1 = jnp.where(len1 == 0, jnp.uint32(0), (full >> shift1) << shift1)
    pfx_eq = ((str_pfx0 & m0) == (u0 & m0)) & ((str_pfx1 & m1) == (u1 & m1))
    r_prefix = ~is_str | (pfx_eq & (size >= i0))

    r_str_eq = jnp.broadcast_to(is_str, out_shape) & hash_eq
    r_str_eq_pre = jnp.broadcast_to(~is_str, out_shape) | hash_eq
    r_null = jnp.broadcast_to(ntype == _T_NULL, out_shape)
    r_bool = (ntype == _T_BOOL) & (num == f0)
    r_num_const = is_num & (num == f0)

    # OBJ_HAS_SLOT: acquired required-slot bit i0 (non-objects pass)
    slot_bit = (jnp.right_shift(acq, jnp.clip(i0, 0, 31)) & 1) != 0
    r_has_slot = ~is_obj | slot_bit

    result = jnp.zeros(out_shape, bool)
    for code, value in [
        (AOP.TYPE_MASK, r_type),
        (AOP.NUM_GE, r_ge),
        (AOP.NUM_GT, r_gt),
        (AOP.NUM_LE, r_le),
        (AOP.NUM_LT, r_lt),
        (AOP.NUM_MULTIPLE, r_mul),
        (AOP.STR_MINLEN, r_str_min),
        (AOP.STR_MAXLEN, r_str_max),
        (AOP.ARR_MINLEN, r_arr_min),
        (AOP.ARR_MAXLEN, r_arr_max),
        (AOP.OBJ_MINPROPS, r_obj_min),
        (AOP.OBJ_MAXPROPS, r_obj_max),
        (AOP.STR_PREFIX, r_prefix),
        (AOP.STR_EQ, r_str_eq),
        (AOP.CONST_NULL, r_null),
        (AOP.CONST_BOOL, r_bool),
        (AOP.CONST_NUM, r_num_const),
        (AOP.STR_EQ_PRE, r_str_eq_pre),
        (AOP.OBJ_HAS_SLOT, r_has_slot),
    ]:
        result = jnp.where(op == code, jnp.broadcast_to(value, out_shape), result)
    return result


def assertion_eval_ref(node_cols: dict, asrt_cols: dict) -> jax.Array:
    """(N, A) int8 pass matrix -- mirror of the dense Pallas kernel."""
    ntype = node_cols["type"].astype(jnp.int32)[:, None]  # (N, 1)
    isint = node_cols["is_int"].astype(bool)[:, None]
    num = node_cols["num"][:, None]
    size = node_cols["size"].astype(jnp.int32)[:, None]
    acq = node_cols["acquired"].astype(jnp.int32)[:, None]
    str_hash = node_cols["str_hash"]  # (N, 8)
    str_pfx = node_cols["str_prefix"]  # (N, 2)

    op = asrt_cols["op"].astype(jnp.int32)[None, :]  # (1, A)
    f0 = asrt_cols["f0"][None, :]
    i0 = asrt_cols["i0"].astype(jnp.int32)[None, :]
    i1 = asrt_cols["i1"].astype(jnp.int32)[None, :]
    u0 = asrt_cols["u0"][None, :]
    u1 = asrt_cols["u1"][None, :]
    a_hash = asrt_cols["hash"]  # (A, 8)

    hash_eq = jnp.all(str_hash[:, None, :] == a_hash[None, :, :], axis=-1)  # (N, A)
    result = _eval_rows_ref(
        ntype, isint, num, size, acq, str_pfx[:, 0:1], str_pfx[:, 1:2],
        op, f0, i0, i1, u0, u1, hash_eq,
    )
    return result.astype(jnp.int8)


def assertion_eval_window_ref(node_cols: dict, w_cols: dict) -> jax.Array:
    """(N, W) int8 pass matrix -- mirror of the windowed Pallas kernel.

    ``w_cols`` holds per-node gathered CSR-window operands: op/f0/i0/i1/
    u0/u1 of shape (N, W) and hash of shape (N, W, 8).  Masked slots carry
    op=-1 and evaluate to 0.
    """
    ntype = node_cols["type"].astype(jnp.int32)[:, None]  # (N, 1)
    isint = node_cols["is_int"].astype(bool)[:, None]
    num = node_cols["num"][:, None]
    size = node_cols["size"].astype(jnp.int32)[:, None]
    acq = node_cols["acquired"].astype(jnp.int32)[:, None]
    str_hash = node_cols["str_hash"]  # (N, 8)
    str_pfx = node_cols["str_prefix"]  # (N, 2)

    op = w_cols["op"].astype(jnp.int32)  # (N, W)
    f0 = w_cols["f0"]
    i0 = w_cols["i0"].astype(jnp.int32)
    i1 = w_cols["i1"].astype(jnp.int32)
    u0 = w_cols["u0"]
    u1 = w_cols["u1"]
    w_hash = w_cols["hash"]  # (N, W, 8)

    hash_eq = jnp.all(str_hash[:, None, :] == w_hash, axis=-1)  # (N, W)
    result = _eval_rows_ref(
        ntype, isint, num, size, acq, str_pfx[:, 0:1], str_pfx[:, 1:2],
        op, f0, i0, i1, u0, u1, hash_eq,
    )
    return result.astype(jnp.int8)
