"""Serving observability: tracing, metrics, profiling, events, SLOs.

Spans + metric registry are DESIGN.md §12; the cost-attribution
profiler, sampled event log, and SLO/burn-rate tracking are §13.

Zero-dependency by design -- the serve stack imports this package
unconditionally, so it must cost nothing when disarmed: ``span()``/
``trace_point()``/``phase()`` pay one module-global ``None`` check (the
``fault_point`` contract), and registry-backed counters are plain
attribute adds.
"""

from .events import EventLog
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    DEFAULT_LATENCY_BUCKETS,
)
from .profile import (
    PhaseStat,
    Profiler,
    phase,
    profiler_armed,
    set_profiler,
)
from .slo import SLObjective, SLOTracker, slo_status
from .stats import RegistryBackedStats
from .trace import (
    Span,
    Tracer,
    set_tracer,
    span,
    trace_point,
    tracer_armed,
)

__all__ = [
    "RegistryBackedStats",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "DEFAULT_LATENCY_BUCKETS",
    "EventLog",
    "PhaseStat",
    "Profiler",
    "phase",
    "profiler_armed",
    "set_profiler",
    "SLObjective",
    "SLOTracker",
    "slo_status",
    "Span",
    "Tracer",
    "set_tracer",
    "span",
    "trace_point",
    "tracer_armed",
]
