"""Serving observability: tracing spans + metric registry (DESIGN.md §12).

Zero-dependency by design -- the serve stack imports this package
unconditionally, so it must cost nothing when disarmed: ``span()``/
``trace_point()`` pay one module-global ``None`` check (the
``fault_point`` contract), and registry-backed counters are plain
attribute adds.
"""

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    DEFAULT_LATENCY_BUCKETS,
)
from .stats import RegistryBackedStats
from .trace import (
    Span,
    Tracer,
    set_tracer,
    span,
    trace_point,
    tracer_armed,
)

__all__ = [
    "RegistryBackedStats",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "DEFAULT_LATENCY_BUCKETS",
    "Span",
    "Tracer",
    "set_tracer",
    "span",
    "trace_point",
    "tracer_armed",
]
