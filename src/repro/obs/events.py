"""Sampled structured request-event log (DESIGN.md §13).

Aggregate metrics (``obs/metrics.py``) answer "how many / how fast on
average"; the event log answers "what did *this* request's life look
like" -- one structured record per sampled request carrying endpoint,
outcome, per-stage timings, and batch id, kept in a bounded ring and
flushable as JSONL.

Design constraints:

- **Bounded**: a fixed-capacity ring of plain dicts; the oldest record
  is overwritten once full.  No allocation beyond the record itself.
- **Sampled deterministically**: ``sample`` is the long-run fraction of
  candidate events recorded.  The schedule is counter-based (record the
  n-th candidate iff ``floor(n * sample)`` advances), so a given rate
  records the *same* subsequence on every run -- reproducible across
  processes, no RNG state to carry, and exact in the long run (never
  "unlucky" bursts of zero samples).
- **Cheap when attached**: the serving hot path asks :meth:`want` (two
  integer ops) before building the record dict, so unsampled requests
  pay almost nothing and a detached engine (``events=None``) pays one
  ``None`` check.
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, IO, List, Optional, Union

__all__ = ["EventLog"]


class EventLog:
    """Fixed-capacity ring of sampled request events, JSONL-flushable."""

    def __init__(self, capacity: int = 4096, sample: float = 1.0):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if not (0.0 <= sample <= 1.0):
            raise ValueError("sample must be in [0, 1]")
        self.capacity = capacity
        self.sample = sample
        self._ring: List[Optional[Dict[str, Any]]] = [None] * capacity
        self._next = 0  # total records ever written
        self._seen = 0  # candidate events offered (want() calls)
        self._quota = 0  # samples granted so far by the schedule

    # -- sampling ----------------------------------------------------------

    def want(self) -> bool:
        """Deterministic sampling decision for the next candidate event.

        Call exactly once per candidate; build + :meth:`emit` the record
        only when it returns True.
        """
        self._seen += 1
        due = int(self._seen * self.sample)
        if due > self._quota:
            self._quota = due
            return True
        return False

    # -- recording ---------------------------------------------------------

    def emit(self, **fields: Any) -> Dict[str, Any]:
        """Record one event (adds a wall-clock ``ts`` unless provided)."""
        record = dict(fields)
        record.setdefault("ts", time.time())
        self._ring[self._next % self.capacity] = record
        self._next += 1
        return record

    # -- views -------------------------------------------------------------

    @property
    def seen(self) -> int:
        """Candidate events offered to the sampler."""
        return self._seen

    @property
    def recorded(self) -> int:
        """Events actually recorded (including ring-evicted ones)."""
        return self._next

    def recent(self) -> List[Dict[str, Any]]:
        """Records still in the ring, oldest first."""
        n = self._next
        if n <= self.capacity:
            return [r for r in self._ring[:n] if r is not None]
        start = n % self.capacity
        out = self._ring[start:] + self._ring[:start]
        return [r for r in out if r is not None]

    def clear(self) -> None:
        self._ring = [None] * self.capacity
        self._next = 0

    def flush(self, dest: Union[str, IO[str]]) -> int:
        """Append the ring's records to ``dest`` as JSONL and clear it.

        ``dest`` is a path (opened in append mode) or a writable text
        file object.  Returns the number of records written.
        """
        records = self.recent()
        if isinstance(dest, str):
            with open(dest, "a", encoding="utf-8") as fp:
                for r in records:
                    fp.write(json.dumps(r, sort_keys=True) + "\n")
        else:
            for r in records:
                dest.write(json.dumps(r, sort_keys=True) + "\n")
        self.clear()
        return len(records)
