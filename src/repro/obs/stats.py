"""Registry-backed stats objects (DESIGN.md §12).

``ServeStats``/``PipelineStats`` historically were plain dataclasses of
ad-hoc integer fields; exporting them meant hand-rolling a formatter per
consumer.  :class:`RegistryBackedStats` keeps the *attribute API* intact
(``stats.received += 1`` still works, tests and examples unchanged)
while every field is now a live :class:`~repro.obs.metrics.Counter`
child of a shared :class:`~repro.obs.metrics.MetricRegistry` -- so one
``render_prometheus()`` exports serving counters, executor launch
timings, and control-plane swaps from the same registry.

Subclasses declare ``PREFIX`` + ``INT_FIELDS``/``FLOAT_FIELDS``;
attribute access is routed through ``__getattr__``/``__setattr__`` to
the backing counters.  ``snapshot()`` returns a plain-dict view and
``reset()`` zeroes only the counters *this stats object owns* (a shared
registry's other families are untouched).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from .metrics import Counter, MetricRegistry

__all__ = ["RegistryBackedStats"]


class RegistryBackedStats:
    """Attribute-compatible stats facade over registry counters."""

    PREFIX: str = ""
    INT_FIELDS: Tuple[str, ...] = ()
    FLOAT_FIELDS: Tuple[str, ...] = ()
    HELP: Dict[str, str] = {}

    def __init__(self, metrics: Optional[MetricRegistry] = None):
        # _stat_children must exist before any routed attribute access
        object.__setattr__(self, "_stat_children", {})
        object.__setattr__(self, "_own", [])
        self.metrics = metrics if metrics is not None else MetricRegistry()
        for name in (*self.INT_FIELDS, *self.FLOAT_FIELDS):
            self._stat_children[name] = self._track(
                self.metrics.counter(
                    f"{self.PREFIX}{name}_total", self.HELP.get(name, "")
                )
            )

    def _track(self, counter: Counter) -> Counter:
        """Register a counter as owned (zeroed by :meth:`reset`)."""
        self._own.append(counter)
        return counter

    def __getattr__(self, name: str) -> Any:
        # only reached when normal lookup fails: stat fields live in the
        # registry, everything else is a genuine AttributeError
        children = object.__getattribute__(self, "_stat_children")
        if name in children:
            return children[name].value
        raise AttributeError(
            f"{type(self).__name__!s} has no attribute {name!r}"
        )

    def __setattr__(self, name: str, value: Any) -> None:
        children = self.__dict__.get("_stat_children")
        if children is not None and name in children:
            children[name].set(value)
        else:
            object.__setattr__(self, name, value)

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time plain-dict view of every scalar field."""
        return {name: c.value for name, c in self._stat_children.items()}

    def reset(self) -> None:
        """Zero every owned counter (other registry families untouched)."""
        own: List[Counter] = self._own
        for c in own:
            c.reset()

    def __repr__(self) -> str:  # debugging/test-failure friendliness
        fields = ", ".join(f"{k}={v!r}" for k, v in self.snapshot().items())
        return f"{type(self).__name__}({fields})"
