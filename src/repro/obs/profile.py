"""Pipeline cost-attribution profiler (DESIGN.md §13).

Phase-level wall-time attribution for the serving/admission pipeline:
*where* does an end-to-end request spend its time -- tokenizer walk vs
key hashing vs column packing, launch compile vs execute, sequential
fallback vs guard checks?  The closed-loop µs/doc aggregates in the
``BENCH_*`` files say *how fast*; this module says *why*.

The seam contract mirrors ``obs/trace.py``'s ``span()`` (and §11's
``fault_point``): module-level :func:`phase` costs exactly one global
``None`` check when no :class:`Profiler` is armed, returning a shared
no-op context manager.  Armed, each phase records two
``perf_counter_ns`` reads and a dict update -- phases are placed at
*batch/stage* granularity (one per launch, one per encode sub-stage),
never per token, so armed overhead stays in the low single-digit
percents.

Attribution semantics: phases nest.  Each :class:`PhaseStat` tracks
``total_ns`` (inclusive) and ``self_ns`` (exclusive -- child phase time
subtracted), so ``sum(self_ns)`` over all phases never double-counts
and can be compared directly against an end-to-end wall-clock window:
``Profiler.coverage(window_ns)`` is the fraction of the window the
instrumented phases explain (the acceptance bar is >=90% at B=4096).
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "PhaseStat",
    "Profiler",
    "set_profiler",
    "profiler_armed",
    "phase",
]


class PhaseStat:
    """Accumulated timing for one named phase."""

    __slots__ = ("name", "calls", "total_ns", "self_ns")

    def __init__(self, name: str):
        self.name = name
        self.calls = 0
        self.total_ns = 0
        self.self_ns = 0

    @property
    def total_us(self) -> float:
        return self.total_ns / 1e3

    @property
    def self_us(self) -> float:
        return self.self_ns / 1e3

    def as_dict(self) -> Dict[str, Any]:
        return {
            "calls": self.calls,
            "total_ns": self.total_ns,
            "self_ns": self.self_ns,
        }


class _PhaseCtx:
    """Context manager for one live phase (returned by ``Profiler.phase``)."""

    __slots__ = ("_prof", "_name", "_t0")

    def __init__(self, prof: "Profiler", name: str):
        self._prof = prof
        self._name = name

    def __enter__(self) -> "_PhaseCtx":
        self._prof._stack.append([self._name, 0])
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: Any) -> None:
        dt = time.perf_counter_ns() - self._t0
        prof = self._prof
        _, child_ns = prof._stack.pop()
        stat = prof._stats.get(self._name)
        if stat is None:
            stat = prof._stats[self._name] = PhaseStat(self._name)
        stat.calls += 1
        stat.total_ns += dt
        stat.self_ns += dt - child_ns
        if prof._stack:
            prof._stack[-1][1] += dt  # bill inclusive time to the parent


class _NoopCtx:
    """Shared do-nothing context manager for the disarmed path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopCtx":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass


_NOOP = _NoopCtx()


class Profiler:
    """Accumulates per-phase wall time with nesting-aware attribution.

    Arm with::

        with Profiler() as prof:
            ...  # instrumented code calls obs.profile.phase(...)
        print(prof.report())

    ``self_ns`` is exclusive time (children subtracted), so summing it
    across phases is double-count-free; ``coverage(window_ns)`` divides
    that sum by an externally measured end-to-end window.
    """

    def __init__(self) -> None:
        self._stats: Dict[str, PhaseStat] = {}
        # live stack of [name, accumulated_child_ns]
        self._stack: List[List[Any]] = []
        self._prev: Optional["Profiler"] = None

    # -- recording ---------------------------------------------------------

    def phase(self, name: str) -> _PhaseCtx:
        return _PhaseCtx(self, name)

    # -- views -------------------------------------------------------------

    def stats(self) -> Dict[str, PhaseStat]:
        return dict(self._stats)

    def attributed_ns(self) -> int:
        """Total exclusive nanoseconds across all phases (no double count)."""
        return sum(s.self_ns for s in self._stats.values())

    def coverage(self, window_ns: int) -> float:
        """Fraction of ``window_ns`` explained by recorded phases."""
        if window_ns <= 0:
            return 0.0
        return self.attributed_ns() / window_ns

    def report(self, window_ns: Optional[int] = None) -> Dict[str, Any]:
        """JSON-ready attribution report, phases sorted by exclusive time.

        With ``window_ns`` (an externally measured end-to-end window) the
        report carries per-phase window fractions plus the aggregate
        coverage and the unattributed remainder.
        """
        ordered = sorted(
            self._stats.values(), key=lambda s: s.self_ns, reverse=True
        )
        phases: Dict[str, Any] = {}
        for s in ordered:
            entry = s.as_dict()
            if window_ns:
                entry["window_frac"] = s.self_ns / window_ns
            phases[s.name] = entry
        out: Dict[str, Any] = {
            "phases": phases,
            "attributed_ns": self.attributed_ns(),
        }
        if window_ns:
            out["window_ns"] = window_ns
            out["coverage"] = self.coverage(window_ns)
            out["unattributed_ns"] = max(0, window_ns - self.attributed_ns())
        return out

    def clear(self) -> None:
        self._stats = {}
        self._stack = []

    # -- arming ------------------------------------------------------------

    def __enter__(self) -> "Profiler":
        self._prev = set_profiler(self)
        return self

    def __exit__(self, *exc: Any) -> None:
        set_profiler(self._prev)
        self._prev = None


# ---------------------------------------------------------------------------
# Module-level seam (one None check when disarmed, like span/fault_point)
# ---------------------------------------------------------------------------


_PROFILER: Optional[Profiler] = None


def set_profiler(prof: Optional[Profiler]) -> Optional[Profiler]:
    """Install (or clear) the process-wide profiler; returns the prior one."""
    global _PROFILER
    prev = _PROFILER
    _PROFILER = prof
    return prev


def profiler_armed() -> bool:
    """True when a profiler is armed -- lets instrumented code pick a
    (more expensive) timed variant only when someone is measuring."""
    return _PROFILER is not None


def phase(name: str) -> Any:
    """Context manager attributing wall time to ``name``; shared no-op
    when disarmed."""
    if _PROFILER is None:
        return _NOOP
    return _PROFILER.phase(name)
