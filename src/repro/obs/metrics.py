"""Zero-dependency metric registry (DESIGN.md §12).

Counters, gauges, and fixed-log-bucket histograms with Prometheus-style
text exposition and a JSON snapshot.  The registry replaces the ad-hoc
``ServeStats``/``PipelineStats`` integer fields: each stats object keeps
its attribute API as a *compatibility view* over registry children, so
``engine.stats.received`` and ``registry.render_prometheus()`` are two
projections of the same storage.

Design constraints (mirroring the ``fault_point`` contract of
``core/outcomes.py``):

- The hot path touches plain Python attributes -- ``Counter.inc`` is an
  integer add, ``Histogram.observe`` is one ``bisect`` call.  No locks,
  no string formatting, no label-dict hashing per observation: callers
  cache child objects once (``registry.counter(...)`` is the slow,
  idempotent lookup) and hit ``.inc()``/``.observe()`` thereafter.
- ``reset()`` zeroes children *in place* so cached references held by
  instrumented code stay valid across benchmark runs.
- Histogram buckets are fixed at construction (default: log-spaced
  base-4 edges from 1µs), so exposition is allocation-free and bucket
  math is a binary search, never a resize.
"""

from __future__ import annotations

import json
from bisect import bisect_right
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "DEFAULT_LATENCY_BUCKETS",
]

Number = Union[int, float]

#: Default histogram edges for latency-in-seconds metrics: log-spaced
#: base-4 from 1µs to ~67s (1e-6 * 4**k).  Twelve finite edges keep the
#: exposition small while spanning sub-µs guard checks to multi-second
#: fallback timeouts; +Inf is implicit.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    1e-6 * 4**k for k in range(13)
)


def _fmt(v: Number) -> str:
    """Prometheus-friendly number rendering (ints stay integral)."""
    if isinstance(v, float):
        if v == float("inf"):
            return "+Inf"
        if v.is_integer() and abs(v) < 1e15:
            return str(int(v))
        return repr(v)
    return str(v)


class Counter:
    """Monotonic (by convention) numeric counter.

    Float-capable so aggregate-seconds counters (``validation_seconds``)
    ride the same machinery.  ``set`` exists for the compatibility view
    (``stats.received += 1`` reads then writes the property).
    """

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def set(self, v: Number) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Point-in-time numeric value (breaker state, queue depth)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Histogram:
    """Fixed-bucket histogram (cumulative-at-exposition, like Prometheus).

    ``buckets`` holds per-bucket (non-cumulative) counts for the finite
    edges plus one overflow slot; exposition accumulates.  ``observe``
    is one ``bisect_right`` + two adds.  ``observe_many`` amortizes a
    batch of identical observations in O(1) -- the serve engine uses it
    to bill a batched launch to per-endpoint request counts without a
    per-document Python loop.
    """

    __slots__ = ("edges", "buckets", "count", "sum")

    def __init__(self, edges: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        self.edges: Tuple[float, ...] = tuple(sorted(edges))
        self.buckets: List[int] = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum: float = 0.0

    def observe(self, v: float) -> None:
        self.buckets[bisect_right(self.edges, v)] += 1
        self.count += 1
        self.sum += v

    def observe_many(self, v: float, n: int) -> None:
        if n <= 0:
            return
        self.buckets[bisect_right(self.edges, v)] += n
        self.count += n
        self.sum += v * n

    def reset(self) -> None:
        for i in range(len(self.buckets)):
            self.buckets[i] = 0
        self.count = 0
        self.sum = 0.0

    def cumulative(self) -> List[Tuple[float, int]]:
        """(upper_edge, cumulative_count) pairs including +Inf."""
        out: List[Tuple[float, int]] = []
        running = 0
        for edge, n in zip(self.edges, self.buckets):
            running += n
            out.append((edge, running))
        out.append((float("inf"), self.count))
        return out


LabelKey = Tuple[Tuple[str, str], ...]


class _Family:
    """One metric name: type, help text, and children keyed by labels."""

    __slots__ = ("name", "kind", "help", "children", "buckets")

    def __init__(
        self,
        name: str,
        kind: str,
        help_text: str,
        buckets: Optional[Sequence[float]] = None,
    ):
        self.name = name
        self.kind = kind  # "counter" | "gauge" | "histogram"
        self.help = help_text
        self.children: Dict[LabelKey, Any] = {}
        self.buckets = tuple(buckets) if buckets is not None else None

    def child(self, labels: Dict[str, str]) -> Any:
        key: LabelKey = tuple(sorted((k, str(v)) for k, v in labels.items()))
        got = self.children.get(key)
        if got is None:
            if self.kind == "counter":
                got = Counter()
            elif self.kind == "gauge":
                got = Gauge()
            else:
                got = Histogram(self.buckets or DEFAULT_LATENCY_BUCKETS)
            self.children[key] = got
        return got


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class MetricRegistry:
    """Namespace of counter/gauge/histogram families.

    ``counter``/``gauge``/``histogram`` are idempotent child lookups --
    call once at wiring time, cache the returned object, mutate it on
    the hot path.  ``render_prometheus()`` emits the text exposition
    format; ``snapshot()`` returns a JSON-serializable dict;
    ``reset()`` zeroes every child in place.
    """

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}

    # -- child accessors ---------------------------------------------------

    def _family(
        self,
        name: str,
        kind: str,
        help_text: str,
        buckets: Optional[Sequence[float]] = None,
    ) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(name, kind, help_text, buckets)
            self._families[name] = fam
        elif fam.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind}, not {kind}"
            )
        return fam

    def counter(self, name: str, help: str = "", **labels: str) -> Counter:
        return self._family(name, "counter", help).child(labels)

    def gauge(self, name: str, help: str = "", **labels: str) -> Gauge:
        return self._family(name, "gauge", help).child(labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        buckets: Optional[Sequence[float]] = None,
        **labels: str,
    ) -> Histogram:
        return self._family(name, "histogram", help, buckets).child(labels)

    # -- views -------------------------------------------------------------

    def family_children(self, name: str) -> Dict[LabelKey, Any]:
        fam = self._families.get(name)
        return fam.children if fam is not None else {}

    def render_prometheus(self) -> str:
        """Prometheus text exposition (HELP/TYPE + one line per child)."""
        lines: List[str] = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key in sorted(fam.children):
                child = fam.children[key]
                if fam.kind in ("counter", "gauge"):
                    lines.append(f"{name}{_label_str(key)} {_fmt(child.value)}")
                else:
                    for edge, cum in child.cumulative():
                        le = (("le", _fmt(edge)),)
                        lines.append(
                            f"{name}_bucket{_label_str(key + le)} {cum}"
                        )
                    lines.append(
                        f"{name}_sum{_label_str(key)} {_fmt(child.sum)}"
                    )
                    lines.append(
                        f"{name}_count{_label_str(key)} {child.count}"
                    )
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable dump of every family and child."""
        out: Dict[str, Any] = {}
        for name in sorted(self._families):
            fam = self._families[name]
            children = []
            for key in sorted(fam.children):
                child = fam.children[key]
                entry: Dict[str, Any] = {"labels": dict(key)}
                if fam.kind in ("counter", "gauge"):
                    entry["value"] = child.value
                else:
                    entry["count"] = child.count
                    entry["sum"] = child.sum
                    entry["buckets"] = [
                        [e if e != float("inf") else "+Inf", c]
                        for e, c in child.cumulative()
                    ]
                children.append(entry)
            out[name] = {"type": fam.kind, "help": fam.help, "children": children}
        return out

    def snapshot_json(self, **kwargs: Any) -> str:
        return json.dumps(self.snapshot(), **kwargs)

    def reset(self) -> None:
        """Zero every child in place (cached references stay valid)."""
        for fam in self._families.values():
            for child in fam.children.values():
                child.reset()
