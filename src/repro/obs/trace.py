"""Zero-dependency tracing core (DESIGN.md §12).

Span/Tracer with monotonic-clock nesting and a ring buffer of recent
spans, plus module-level ``span()``/``trace_point()`` seams that mirror
the ``fault_point`` pattern of ``core/outcomes.py``: the clean path pays
exactly one global ``None`` check when no tracer is armed.

Instrumentation rule of thumb: trace per *batch* or per *stage*, never
per document -- a span costs two ``time.monotonic_ns()`` calls and one
ring-buffer append, which is noise at batch granularity and a disaster
at document granularity.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "Span",
    "Tracer",
    "set_tracer",
    "tracer_armed",
    "span",
    "trace_point",
]


@dataclass
class Span:
    """One completed (or point-in-time) trace record.

    ``dur_ns`` is -1 for point events; ``depth`` is the nesting level at
    entry so renderers can indent without replaying the stack.
    """

    name: str
    t0_ns: int
    dur_ns: int = -1
    depth: int = 0
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def dur_us(self) -> float:
        return self.dur_ns / 1e3 if self.dur_ns >= 0 else -1.0


class _SpanCtx:
    """Context manager for one live span (returned by ``Tracer.span``)."""

    __slots__ = ("_tracer", "_name", "_attrs", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> "_SpanCtx":
        self._depth = self._tracer._depth
        self._tracer._depth += 1
        self._t0 = time.monotonic_ns()
        return self

    def __exit__(self, *exc: Any) -> None:
        dur = time.monotonic_ns() - self._t0
        self._tracer._depth -= 1
        self._tracer._record(
            Span(self._name, self._t0, dur, self._depth, self._attrs)
        )


class _NoopCtx:
    """Shared do-nothing context manager for the disarmed path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopCtx":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass


_NOOP = _NoopCtx()


class Tracer:
    """Ring buffer of recent spans with explicit nesting depth.

    Appends overwrite the oldest entry once ``capacity`` is reached
    (single-threaded "lock-free-ish": one index increment per record,
    no allocation beyond the Span itself).  Arm with::

        with Tracer(capacity=512) as tr:
            ...  # instrumented code calls obs.trace.span(...)
        spans = tr.recent()
    """

    def __init__(self, capacity: int = 1024):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._ring: List[Optional[Span]] = [None] * capacity
        self._next = 0  # total spans ever recorded
        self._depth = 0
        self._prev: Optional["Tracer"] = None

    # -- recording ---------------------------------------------------------

    def _record(self, s: Span) -> None:
        self._ring[self._next % self.capacity] = s
        self._next += 1

    def span(self, name: str, **attrs: Any) -> _SpanCtx:
        return _SpanCtx(self, name, attrs)

    def point(self, name: str, **attrs: Any) -> None:
        self._record(
            Span(name, time.monotonic_ns(), -1, self._depth, attrs)
        )

    # -- views -------------------------------------------------------------

    @property
    def recorded(self) -> int:
        """Total spans recorded since construction (including evicted)."""
        return self._next

    def recent(self) -> List[Span]:
        """Spans still in the ring, oldest first."""
        n = self._next
        if n <= self.capacity:
            return [s for s in self._ring[:n] if s is not None]
        start = n % self.capacity
        out = self._ring[start:] + self._ring[:start]
        return [s for s in out if s is not None]

    def clear(self) -> None:
        self._ring = [None] * self.capacity
        self._next = 0
        self._depth = 0

    # -- arming ------------------------------------------------------------

    def __enter__(self) -> "Tracer":
        self._prev = set_tracer(self)
        return self

    def __exit__(self, *exc: Any) -> None:
        set_tracer(self._prev)
        self._prev = None


# ---------------------------------------------------------------------------
# Module-level seams (one None check when disarmed, like fault_point)
# ---------------------------------------------------------------------------


_TRACER: Optional[Tracer] = None


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or clear) the process-wide tracer; returns the prior one."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


def tracer_armed() -> bool:
    """True when a tracer is armed -- lets hot paths skip building
    expensive span attributes."""
    return _TRACER is not None


def span(name: str, **attrs: Any) -> Any:
    """Context manager for a named span; shared no-op when disarmed."""
    if _TRACER is None:
        return _NOOP
    return _TRACER.span(name, **attrs)


def trace_point(name: str, **attrs: Any) -> None:
    """Point-in-time trace event; no-op unless a tracer is armed."""
    if _TRACER is not None:
        _TRACER.point(name, **attrs)
