"""Per-endpoint latency SLOs and error-budget burn rates (DESIGN.md §13).

An SLO here is "fraction ``target`` of requests complete within
``objective_s`` seconds", evaluated directly against the
``serve_request_seconds{endpoint}`` histograms that the engine already
maintains -- no second measurement path, no extra hot-path cost.

Formulas (standard SRE error-budget arithmetic):

- ``good_ratio = good / count`` where ``good`` is the (interpolated)
  cumulative histogram count at ``objective_s``;
- ``error_budget = 1 - target`` (the tolerated bad fraction);
- ``burn_rate = (1 - good_ratio) / error_budget`` -- 1.0 means the
  endpoint is consuming its budget exactly as provisioned, >1 means it
  will exhaust the budget early (2.0 = twice as fast), <1 means margin.

Because the histogram buckets are fixed log-spaced edges, an objective
that is not exactly a bucket edge is resolved by *linear interpolation
within its bucket* -- documented imprecision bounded by one bucket's
width (base-4 edges: at most the span between adjacent powers of four).
Choose objectives on bucket edges when exactness matters.

:class:`SLOTracker` adds windowed burn rates: each :meth:`update` diffs
the histogram against the previous call's totals, so the ``window_*``
fields reflect only traffic since the last refresh (the control plane
polls this at its own cadence; two successive polls bound the window).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

from .metrics import Histogram

__all__ = ["SLObjective", "good_count", "slo_status", "SLOTracker"]


@dataclass(frozen=True)
class SLObjective:
    """A latency objective: ``target`` fraction within ``objective_s``."""

    objective_s: float = 0.1
    target: float = 0.99

    def __post_init__(self) -> None:
        if self.objective_s <= 0:
            raise ValueError("objective_s must be positive")
        if not (0.0 < self.target < 1.0):
            raise ValueError("target must be in (0, 1)")

    @property
    def error_budget(self) -> float:
        return 1.0 - self.target


def good_count(hist: Histogram, objective_s: float) -> float:
    """Cumulative observation count at ``objective_s``.

    Exact when the objective is a bucket edge; linearly interpolated
    within the containing bucket otherwise.  Past the last finite edge
    the count is clamped to the last finite cumulative value (the +Inf
    bucket cannot be interpolated, so out-of-range observations are
    conservatively counted as bad).
    """
    prev_edge = 0.0
    prev_cum = 0
    running = 0
    for edge, n in zip(hist.edges, hist.buckets):
        running += n
        if objective_s >= edge:
            prev_edge, prev_cum = edge, running
            continue
        span = edge - prev_edge
        frac = (objective_s - prev_edge) / span if span > 0 else 0.0
        return prev_cum + frac * (running - prev_cum)
    return float(prev_cum)


def slo_status(hist: Histogram, slo: SLObjective) -> Dict[str, Any]:
    """Cumulative SLO view of one latency histogram."""
    count = hist.count
    good = good_count(hist, slo.objective_s)
    good_ratio = (good / count) if count else 1.0
    return {
        "objective_s": slo.objective_s,
        "target": slo.target,
        "count": count,
        "good": good,
        "good_ratio": good_ratio,
        "error_budget": slo.error_budget,
        "burn_rate": (1.0 - good_ratio) / slo.error_budget,
    }


class SLOTracker:
    """Windowed burn-rate tracking over a live histogram.

    Stateful companion to :func:`slo_status`: remembers the (count,
    good) totals of the previous :meth:`update`, so each call also
    reports the burn rate of just the traffic observed since then.
    """

    __slots__ = ("slo", "_last")

    def __init__(self, slo: SLObjective):
        self.slo = slo
        self._last: Tuple[int, float] = (0, 0.0)

    def update(self, hist: Histogram) -> Dict[str, Any]:
        out = slo_status(hist, self.slo)
        prev_count, prev_good = self._last
        d_count = out["count"] - prev_count
        d_good = out["good"] - prev_good
        if d_count > 0:
            window_ratio = min(1.0, max(0.0, d_good / d_count))
        else:
            window_ratio = 1.0
        out["window_count"] = d_count
        out["window_good_ratio"] = window_ratio
        out["window_burn_rate"] = (1.0 - window_ratio) / self.slo.error_budget
        self._last = (out["count"], out["good"])
        return out
