"""Core transformer layers: RMSNorm, RoPE, GQA attention, SwiGLU.

Parameters are plain pytrees (dicts of jnp arrays).  Initialisers take an
explicit PRNG key and a dtype; every layer exposes ``init`` and pure apply
functions so the stack composes under ``jax.lax.scan`` and ``pjit``.

Weight-name conventions carry *logical axis* metadata (sharding/rules.py
maps logical axes -> mesh axes): ``("embed", "heads")`` etc.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(cfg: ArchConfig, width: Optional[int] = None) -> Params:
    return {"scale": jnp.ones(width or cfg.d_model, cfg.pdtype())}


def rmsnorm(params: Params, x: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Grouped-query attention
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ArchConfig) -> Params:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.pdtype()
    keys = jax.random.split(key, 4)
    params = {
        "wq": dense_init(keys[0], (d, h, hd), dt),
        "wk": dense_init(keys[1], (d, kvh, hd), dt),
        "wv": dense_init(keys[2], (d, kvh, hd), dt),
        "wo": dense_init(keys[3], (h, hd, d), dt),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h, hd), dt)
        params["bk"] = jnp.zeros((kvh, hd), dt)
        params["bv"] = jnp.zeros((kvh, hd), dt)
    return params


def _qkv(params: Params, cfg: ArchConfig, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _gqa_scores(q: jax.Array, k: jax.Array, n_rep: int) -> jax.Array:
    """q: (B, Sq, H, Dh), k: (B, Sk, KVH, Dh) -> (B, H, Sq, Sk)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, sq, kvh, n_rep, hd)
    scores = jnp.einsum("bsgrk,btgk->bgrst", qg, k)
    return scores.reshape(b, h, sq, k.shape[1])


def _gqa_values(probs: jax.Array, v: jax.Array, n_rep: int) -> jax.Array:
    """probs: (B, H, Sq, Sk), v: (B, Sk, KVH, Dh) -> (B, Sq, H, Dh)."""
    b, h, sq, sk = probs.shape
    kvh = v.shape[2]
    pg = probs.reshape(b, kvh, n_rep, sq, sk)
    out = jnp.einsum("bgrst,btgk->bsgrk", pg, v)
    return out.reshape(b, sq, h, v.shape[3])


# full (B, H, S, S) score tensors blow HBM for archs whose head count does
# not divide the model axis (qwen 40H, arctic 56H, phi4 24H stay unsharded
# on heads); the chunked path scans query blocks instead (flash-attention
# memory shape).  Cq=256 keeps the worst case (arctic: B_loc=16 x 56H x
# 256 x 4096 x f32) under ~4 GiB.
CHUNKED_ATTN_THRESHOLD = 4096
Q_CHUNK = 256


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-token int8 quantization of K/V: (values (B,S,KVH,Dh), scales
    (B,S)).  Per-token (not per-head) scales keep the scale tensor small
    enough to replicate when head_dim is the sharded cache dim
    (DESIGN.md §7)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-1, -2)) / 127.0 + 1e-8
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None, None]), -127, 127
    )
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _chunk_size(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is <= target (prefix-extended
    sequences like 33024 are not multiples of 256)."""
    cq = min(target, s)
    while s % cq:
        cq -= 1
    return cq


def _chunked_causal_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, n_rep: int, scale: float, dtype
) -> jax.Array:
    """Scan over query chunks: peak scores buffer is (B, H, Cq, S)."""
    b, s, h, hd = q.shape
    cq = _chunk_size(s, Q_CHUNK)
    n_chunks = s // cq
    q_chunks = q.reshape(b, n_chunks, cq, h, hd).swapaxes(0, 1)
    key_pos = jnp.arange(s)

    def body(_, args):
        i, qc = args
        scores = _gqa_scores(qc, k, n_rep) * scale  # (B, H, Cq, S)
        q_pos = i * cq + jnp.arange(cq)
        mask = key_pos[None, :] <= q_pos[:, None]
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(dtype)
        return None, _gqa_values(probs, v, n_rep)  # (B, Cq, H, Dh)

    # remat per chunk: backward recomputes scores/probs instead of saving
    # (B, H, Cq, S) x n_chunks -- the flash-attention trade
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    _, chunks = jax.lax.scan(body, None, (jnp.arange(n_chunks), q_chunks))
    return chunks.swapaxes(0, 1).reshape(b, s, h, hd)


def attention(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S)
    kv_cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Any]:
    """Full-sequence (train/prefill) or incremental (decode) attention.

    Decode: ``x`` is (B, 1, D), ``kv_cache`` is {"k", "v"[, "k_scale",
    "v_scale"]} with (B, S_max, KVH, Dh) layout (int8 + scales when the
    config selects a quantized cache), ``cache_index`` the current length.
    """
    from ..sharding.constraints import constrain, model_axis_divides

    n_rep = cfg.n_heads // cfg.n_kv_heads
    scale = 1.0 / np.sqrt(cfg.head_dim)
    q, k, v = _qkv(params, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # Pin Q/K/V layouts BEFORE any chunk scan: with a seq-sharded residual
    # stream XLA otherwise re-all-gathers K/V inside every query-chunk
    # iteration (22 TB/device of prefill collectives; EXPERIMENTS.md §Perf
    # A4).  CAUTION: with_sharding_constraint None-dims mean *replicated*,
    # not "unconstrained" (§Perf A5, first attempt refuted: replicated-Q
    # attention collapsed qwen/arctic/phi4 useful-ratio 0.75 -> 0.33).
    #   heads divide the model axis -> Megatron head sharding;
    #   otherwise -> shard K/V on the *key-sequence* dim: scores inherit
    #   the Sk sharding (TP of the quadratic work without head splits) and
    #   softmax/value reductions become small all-reduces.
    if kv_cache is None:  # train/prefill full-sequence paths only
        if model_axis_divides(cfg.n_heads):
            q = constrain(q, "batch", None, "model", None)
        if model_axis_divides(cfg.n_kv_heads):
            k = constrain(k, "batch", None, "model", None)
            v = constrain(v, "batch", None, "model", None)
        else:
            k = constrain(k, "batch", "model", None, None)
            v = constrain(v, "batch", "model", None, None)

    if kv_cache is not None:
        idx = cache_index
        quantized = "k_scale" in kv_cache
        if quantized:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], kq, idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], vq, idx, axis=1)
            cks = jax.lax.dynamic_update_slice_in_dim(kv_cache["k_scale"], ks, idx, axis=1)
            cvs = jax.lax.dynamic_update_slice_in_dim(kv_cache["v_scale"], vs, idx, axis=1)
            k_full = ck.astype(x.dtype) * cks[..., None, None].astype(x.dtype)
            v_full = cv.astype(x.dtype) * cvs[..., None, None].astype(x.dtype)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), idx, axis=1
            )
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), idx, axis=1
            )
            k_full, v_full = ck, cv
            new_cache = {"k": ck, "v": cv}
        s_max = k_full.shape[1]
        scores = _gqa_scores(q, k_full, n_rep) * scale  # (B, H, 1, S_max)
        key_pos = jnp.arange(s_max)
        mask = key_pos[None, None, None, :] <= (idx + jnp.arange(x.shape[1]))[None, None, :, None]
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = _gqa_values(probs, v_full, n_rep)
    else:
        if causal and x.shape[1] >= CHUNKED_ATTN_THRESHOLD:
            out = _chunked_causal_attention(q, k, v, n_rep, scale, x.dtype)
        else:
            scores = _gqa_scores(q, k, n_rep) * scale  # (B, H, S, S)
            if causal:
                s = x.shape[1]
                mask = jnp.tril(jnp.ones((s, s), bool))
                scores = jnp.where(mask[None, None], scores, -1e30)
            probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
            out = _gqa_values(probs, v, n_rep)
        new_cache = (k, v)  # prefill returns fresh K/V for cache seeding
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def swiglu_init(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.pdtype()
    keys = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(keys[0], (d, f), dt),
        "w_up": dense_init(keys[1], (d, f), dt),
        "w_down": dense_init(keys[2], (f, d), dt),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", gate * up, params["w_down"])


# ---------------------------------------------------------------------------
# Embeddings / head
# ---------------------------------------------------------------------------


def embedding_init(key, cfg: ArchConfig) -> Params:
    dt = cfg.pdtype()
    keys = jax.random.split(key, 2)
    vp = cfg.padded_vocab  # padded so the vocab dim shards (DESIGN.md §5)
    params = {"tokens": dense_init(keys[0], (vp, cfg.d_model), dt, scale=1.0)}
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], (cfg.d_model, vp), dt)
    return params


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tokens"], tokens, axis=0)


def unembed(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["tokens"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padded vocab positions so softmax/argmax never select them
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits
