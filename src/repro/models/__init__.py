"""LM substrate: composable decoder stacks for the assigned architectures."""

from .config import ArchConfig, LayerSpec
from .model import Model

__all__ = ["ArchConfig", "LayerSpec", "Model"]
