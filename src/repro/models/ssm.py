"""State-space and linear-attention mixers: Mamba (Jamba) and RWKV-6 (Finch).

Both are implemented in chunked form: a ``lax.scan`` over sequence chunks
carries the recurrent state, while work *within* a chunk is parallel
(associative scan for Mamba; decay-cumprod linear attention for RWKV-6).
This is the TPU analogue of the CUDA selective-scan kernel: the chunk size
bounds the materialised (B, chunk, D_inner, N) tensor to VMEM-friendly
sizes, and the cross-chunk dependency is a tiny state tensor.

Decode performs the exact recurrence, one step per token, O(1) in context
length -- which is why these two architectures run the ``long_500k`` shape
while pure-attention models skip it.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.constraints import constrain
from .config import ArchConfig
from .layers import dense_init

Params = Dict[str, Any]

MAMBA_CHUNK = 256
RWKV_CHUNK = 64


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------


def mamba_init(key, cfg: ArchConfig) -> Params:
    d, di, n, kconv = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dt_rank = max(1, d // 16)
    dt = cfg.pdtype()
    keys = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(keys[0], (d, 2 * di), dt),
        "conv_w": dense_init(keys[1], (kconv, di), dt, scale=1.0 / np.sqrt(kconv)),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(keys[2], (di, dt_rank + 2 * n), dt),
        "dt_proj": dense_init(keys[3], (dt_rank, di), dt),
        "dt_bias": jnp.zeros((di,), dt),
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[4], (di, d), dt),
    }


def _mamba_discretize(params, cfg: ArchConfig, xz: jax.Array):
    """Project a chunk to (dA, dBx, C, z, gate-path x) tensors."""
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = max(1, cfg.d_model // 16)
    x, z = jnp.split(xz, 2, axis=-1)  # (B, C, Di) each
    proj = jnp.einsum("bci,ir->bcr", x, params["x_proj"])
    dt_r, b_mat, c_mat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt_full = jax.nn.softplus(
        jnp.einsum("bcr,ri->bci", dt_r, params["dt_proj"]) + params["dt_bias"]
    ).astype(jnp.float32)
    a = -jnp.exp(params["A_log"])  # (Di, N)
    dA = jnp.exp(dt_full[..., None] * a)  # (B, C, Di, N)
    dBx = (
        dt_full[..., None]
        * b_mat[:, :, None, :].astype(jnp.float32)
        * x[..., None].astype(jnp.float32)
    )  # (B, C, Di, N)
    return x, z, dA, dBx, c_mat


def _mamba_chunk_scan(h0: jax.Array, dA: jax.Array, dBx: jax.Array):
    """Parallel in-chunk scan: h_t = dA_t * h_{t-1} + dBx_t, given h0."""

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_acc, b_acc = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = a_acc * h0[:, None] + b_acc  # (B, C, Di, N)
    return h, h[:, -1]


def mamba_forward(
    params: Params,
    cfg: ArchConfig,
    u: jax.Array,  # (B, S, D)
    chunk: int = MAMBA_CHUNK,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence Mamba; returns output and final recurrent state."""
    b, s, d = u.shape
    di, n, kconv = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz_all = jnp.einsum("bsd,de->bse", u, params["in_proj"])  # (B, S, 2Di)
    # pin the inner (channel) dim on the model axis: the whole selective
    # scan is channel-independent, so Di shards cleanly (tensor parallel)
    xz_all = constrain(xz_all, "batch", None, "model")

    x_all = xz_all[..., :di]
    # causal depthwise conv over the whole sequence
    x_pad = jnp.pad(x_all, ((0, 0), (kconv - 1, 0), (0, 0)))
    conv = sum(
        x_pad[:, i : i + s] * params["conv_w"][i][None, None, :] for i in range(kconv)
    ) + params["conv_b"]
    x_conv = jax.nn.silu(conv)
    xz_all = jnp.concatenate([x_conv, xz_all[..., di:]], axis=-1)

    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    xz_chunks = xz_all.reshape(b, n_chunks, chunk, 2 * di).swapaxes(0, 1)

    def step(h, xz):
        xz = constrain(xz, "batch", None, "model")
        x, z, dA, dBx, c_mat = _mamba_discretize(params, cfg, xz)
        dA = constrain(dA, "batch", None, "model", None)
        dBx = constrain(dBx, "batch", None, "model", None)
        h_all, h_last = _mamba_chunk_scan(h, dA, dBx)
        y = jnp.einsum("bcin,bcn->bci", h_all, c_mat.astype(jnp.float32))
        y = y + params["D"] * x.astype(jnp.float32)
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
        return constrain(h_last, "batch", "model", None), y

    h0 = constrain(jnp.zeros((b, di, n), jnp.float32), "batch", "model", None)
    # remat the chunk body: backward recomputes the discretised (B, C, Di,
    # N) tensors instead of saving them per chunk (441 GiB -> HBM-viable
    # for jamba train_4k; see EXPERIMENTS.md §Perf)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    h_final, y_chunks = jax.lax.scan(step, h0, xz_chunks)
    y = y_chunks.swapaxes(0, 1).reshape(b, s, di)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    state = {
        "h": h_final,
        "conv": x_all[:, s - (kconv - 1) :, :] if s >= kconv - 1 else x_all,
    }
    return out, state


def mamba_decode_step(
    params: Params, cfg: ArchConfig, u: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token Mamba step.  u: (B, 1, D); state: {h (B,Di,N), conv (B,k-1,Di)}."""
    b = u.shape[0]
    di, n, kconv = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = jnp.einsum("bsd,de->bse", u, params["in_proj"])  # (B, 1, 2Di)
    x_new = xz[..., :di]  # (B, 1, Di)
    window = jnp.concatenate([state["conv"], x_new], axis=1)  # (B, k, Di)
    conv = (
        jnp.einsum("bki,ki->bi", window, params["conv_w"]) + params["conv_b"]
    )[:, None, :]
    x_conv = jax.nn.silu(conv)
    xz = jnp.concatenate([x_conv, xz[..., di:]], axis=-1)
    x, z, dA, dBx, c_mat = _mamba_discretize(params, cfg, xz)
    h = dA[:, 0] * state["h"] + dBx[:, 0]  # (B, Di, N)
    y = jnp.einsum("bin,bn->bi", h, c_mat[:, 0].astype(jnp.float32))
    y = y + params["D"] * x[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(u.dtype)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"])[:, None, :]
    return out, {"h": h, "conv": window[:, 1:]}


def mamba_state_init(cfg: ArchConfig, batch: int) -> Dict[str, jax.Array]:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), cfg.dtype()),
    }


# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay linear attention
# ---------------------------------------------------------------------------


def rwkv6_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    n_heads = max(1, d // 64)
    dt = cfg.pdtype()
    keys = jax.random.split(key, 7)
    return {
        "w_r": dense_init(keys[0], (d, d), dt),
        "w_k": dense_init(keys[1], (d, d), dt),
        "w_v": dense_init(keys[2], (d, d), dt),
        "w_g": dense_init(keys[3], (d, d), dt),
        "w_decay": dense_init(keys[4], (d, d), dt, scale=0.01),
        "decay_bias": jnp.full((d,), -6.0, jnp.float32),  # slow default decay
        "bonus": jnp.zeros((n_heads, 64), jnp.float32),  # 'u' first-token boost
        "w_o": dense_init(keys[5], (d, d), dt),
        "shift_mix": jnp.full((d,), 0.5, dt),  # token-shift interpolation
    }


def _rwkv_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads)


def rwkv6_forward(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,  # (B, S, D)
    chunk: int = RWKV_CHUNK,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s, d = x.shape
    n_heads = max(1, d // 64)
    hd = d // n_heads

    # token shift: mix current with previous token
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xm = x + params["shift_mix"] * (x_prev - x)

    r = _rwkv_heads(jnp.einsum("bsd,de->bse", xm, params["w_r"]), n_heads)
    k = _rwkv_heads(jnp.einsum("bsd,de->bse", xm, params["w_k"]), n_heads)
    v = _rwkv_heads(jnp.einsum("bsd,de->bse", xm, params["w_v"]), n_heads)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xm, params["w_g"]))
    # data-dependent per-channel decay in (0, 1)
    w = jnp.exp(
        -jnp.exp(
            (jnp.einsum("bsd,de->bse", xm, params["w_decay"]).astype(jnp.float32))
            + params["decay_bias"]
        )
    )
    w = _rwkv_heads(w, n_heads)  # (B, S, H, hd)

    chunk = min(chunk, s)
    assert s % chunk == 0
    n_chunks = s // chunk

    rc = r.reshape(b, n_chunks, chunk, n_heads, hd).swapaxes(0, 1)
    kc = k.reshape(b, n_chunks, chunk, n_heads, hd).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, n_heads, hd).swapaxes(0, 1)
    wc = w.reshape(b, n_chunks, chunk, n_heads, hd).swapaxes(0, 1)
    u = params["bonus"]  # (H, hd)

    def step(state, inputs):
        rr, kk, vv, ww = (t.astype(jnp.float32) for t in inputs)  # (B, C, H, hd)
        # cumulative decay within the chunk: P_t = prod_{j<=t} w_j
        logw = jnp.log(jnp.maximum(ww, 1e-12))
        cum = jnp.cumsum(logw, axis=1)  # (B, C, H, hd)
        p_incl = jnp.exp(cum)
        p_excl = jnp.exp(cum - logw)  # prod_{j<t}
        # inter-chunk: r_t . (P_excl_t * state)
        inter = jnp.einsum("bchk,bhkl->bchl", rr * p_excl, state)
        # intra-chunk: sum_{j<t} (r_t P_excl_t / P_incl_j) (k_j . ) v_j + bonus diag
        r_hat = rr * p_excl
        k_hat = kk / jnp.maximum(p_incl, 1e-12)
        att = jnp.einsum("bchk,bjhk->bhcj", r_hat, k_hat)  # (B, H, C, C)
        c_len = att.shape[-1]
        mask = jnp.tril(jnp.ones((c_len, c_len), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        intra = jnp.einsum("bhcj,bjhl->bchl", att, vv)
        # current-token bonus path: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bchk,bchk->bch", rr, u[None, None] * kk)
        cur = bonus[..., None] * vv
        out = inter + intra + cur  # (B, C, H, hd)
        # state update: S' = diag(P_incl_T) S + sum_j (P_incl_T/P_incl_j) k_j v_j
        p_total = p_incl[:, -1]  # (B, H, hd)
        scale = p_total[:, None] / jnp.maximum(p_incl, 1e-12)  # (B, C, H, hd)
        outer = jnp.einsum("bchk,bchl->bhkl", kk * scale, vv)
        new_state = p_total[..., None] * state + outer
        return new_state, out

    state0 = jnp.zeros((b, n_heads, hd, hd), jnp.float32)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    state_f, out_chunks = jax.lax.scan(step, state0, (rc, kc, vc, wc))
    out = out_chunks.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    out = out * g
    out = jnp.einsum("bsd,de->bse", out, params["w_o"])
    return out, {"state": state_f, "x_last": x[:, -1]}


def rwkv6_decode_step(
    params: Params, cfg: ArchConfig, x: jax.Array, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token RWKV step; O(1) in context length."""
    b, _, d = x.shape
    n_heads = max(1, d // 64)
    hd = d // n_heads
    xt = x[:, 0]  # (B, D)
    xm = xt + params["shift_mix"] * (cache["x_last"] - xt)

    def heads(t):
        return t.reshape(b, n_heads, hd)

    r = heads(xm @ params["w_r"]).astype(jnp.float32)
    k = heads(xm @ params["w_k"]).astype(jnp.float32)
    v = heads(xm @ params["w_v"]).astype(jnp.float32)
    g = jax.nn.silu(xm @ params["w_g"])
    w = jnp.exp(
        -jnp.exp((xm @ params["w_decay"]).astype(jnp.float32) + params["decay_bias"])
    )
    w = heads(w)
    state = cache["state"]  # (B, H, hd, hd)
    u = params["bonus"]
    kv = jnp.einsum("bhk,bhl->bhkl", k, v)
    out = jnp.einsum("bhk,bhkl->bhl", r, state + u[None, :, :, None] * kv)
    new_state = w[..., None] * state + kv
    out = out.reshape(b, d).astype(x.dtype) * g
    out = (out @ params["w_o"])[:, None, :]
    return out, {"state": new_state, "x_last": xt}


def rwkv6_state_init(cfg: ArchConfig, batch: int) -> Dict[str, jax.Array]:
    d = cfg.d_model
    n_heads = max(1, d // 64)
    hd = d // n_heads
    return {
        "state": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        "x_last": jnp.zeros((batch, d), cfg.dtype()),
    }


# RWKV channel mix (used as the 'ffn' for rwkv blocks)


def rwkv_channel_mix_init(key, cfg: ArchConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.pdtype()
    keys = jax.random.split(key, 3)
    return {
        "w_k": dense_init(keys[0], (d, f), dt),
        "w_v": dense_init(keys[1], (f, d), dt),
        "w_r": dense_init(keys[2], (d, d), dt),
        "shift_mix": jnp.full((d,), 0.5, dt),
    }


def rwkv_channel_mix(params: Params, x: jax.Array) -> jax.Array:
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xm = x + params["shift_mix"] * (x_prev - x)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xm, params["w_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, params["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xm, params["w_r"]))
    return r * kv
