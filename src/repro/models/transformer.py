"""Composable decoder stack: scan-over-periods with heterogeneous layers.

The model is ``n_periods`` repetitions of a static *period* (list of
LayerSpec).  All parameters are stacked on a leading period axis and the
depth dimension lowers as a single ``jax.lax.scan`` -- one compiled period
body regardless of depth (compile-time and HBM win; XLA keeps weights
sharded per the param specs and the scan carries only activations).

Each layer is pre-norm residual:  x += mixer(norm(x));  x += ffn(norm(x)).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as M
from . import ssm as S
from ..sharding.constraints import constrain_bsd
from .config import ArchConfig, LayerSpec

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Period init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ArchConfig, spec: LayerSpec) -> Params:
    keys = jax.random.split(key, 4)
    p: Params = {"norm_mixer": L.rmsnorm_init(cfg), "norm_ffn": L.rmsnorm_init(cfg)}
    if spec.mixer == "attention":
        p["attn"] = L.attention_init(keys[0], cfg)
    elif spec.mixer == "mamba":
        p["mamba"] = S.mamba_init(keys[0], cfg)
    elif spec.mixer == "rwkv6":
        p["rwkv"] = S.rwkv6_init(keys[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "dense":
        p["ffn"] = L.swiglu_init(keys[1], cfg)
    elif spec.ffn == "moe":
        p["moe"] = M.moe_init(keys[1], cfg)
    elif spec.ffn == "none":
        p["cmix"] = S.rwkv_channel_mix_init(keys[1], cfg)
    else:
        raise ValueError(spec.ffn)
    return p


def init_stack(key, cfg: ArchConfig) -> Params:
    """Stacked parameters: each leaf gains a leading (n_periods,) axis."""
    period_keys = jax.random.split(key, cfg.n_periods)

    def one_period(k):
        lkeys = jax.random.split(k, len(cfg.period))
        return {
            f"layer{i}": _layer_init(lkeys[i], cfg, spec)
            for i, spec in enumerate(cfg.period)
        }

    return jax.vmap(one_period)(period_keys)


# ---------------------------------------------------------------------------
# Cache structure (decode)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    """Per-period-position caches stacked on a leading (n_periods,) axis."""

    def one_period(_):
        cache: Params = {}
        for i, spec in enumerate(cfg.period):
            if spec.mixer == "attention":
                kvh, hd = cfg.n_kv_heads, cfg.head_dim
                if cfg.kv_cache_dtype == "int8":
                    cache[f"layer{i}"] = {
                        "k": jnp.zeros((batch, max_len, kvh, hd), jnp.int8),
                        "v": jnp.zeros((batch, max_len, kvh, hd), jnp.int8),
                        "k_scale": jnp.zeros((batch, max_len), jnp.float32),
                        "v_scale": jnp.zeros((batch, max_len), jnp.float32),
                    }
                else:
                    cache[f"layer{i}"] = {
                        "k": jnp.zeros((batch, max_len, kvh, hd), cfg.dtype()),
                        "v": jnp.zeros((batch, max_len, kvh, hd), cfg.dtype()),
                    }
            elif spec.mixer == "mamba":
                cache[f"layer{i}"] = S.mamba_state_init(cfg, batch)
            elif spec.mixer == "rwkv6":
                cache[f"layer{i}"] = S.rwkv6_state_init(cfg, batch)
        return cache

    return jax.vmap(one_period)(jnp.arange(cfg.n_periods))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _apply_layer_train(
    p: Params, *, cfg: ArchConfig, spec: LayerSpec, x: jax.Array, positions: jax.Array
) -> jax.Array:
    h = L.rmsnorm(p["norm_mixer"], x, cfg.norm_eps)
    if spec.mixer == "attention":
        mixed, _ = L.attention(p["attn"], cfg, h, positions)
    elif spec.mixer == "mamba":
        mixed, _ = S.mamba_forward(p["mamba"], cfg, h)
    else:
        mixed, _ = S.rwkv6_forward(p["rwkv"], cfg, h)
    x = x + mixed
    h = L.rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
    if spec.ffn == "dense":
        x = x + L.swiglu(p["ffn"], h)
    elif spec.ffn == "moe":
        x = x + M.moe_apply(p["moe"], cfg, h)
    else:
        x = x + S.rwkv_channel_mix(p["cmix"], h)
    return x


def forward_train(
    stack: Params,
    cfg: ArchConfig,
    x: jax.Array,  # (B, S, D) embedded inputs
    positions: jax.Array,  # (B, S)
    remat: bool = True,
) -> jax.Array:
    """Scan the stacked periods over the embedded sequence."""

    # NOTE: per-layer nested remat inside the period was tried and refuted:
    # +19% recompute FLOPs with no peak-memory win (EXPERIMENTS.md §Perf,
    # jamba iteration 3) -- period-level remat is the right granularity.
    def period_body(carry, period_params):
        # seq-sharded carry = Megatron sequence parallelism: the saved
        # residual stack shrinks by the model-axis size
        h = constrain_bsd(carry, seq_shard=True)
        for i, spec in enumerate(cfg.period):
            h = _apply_layer_train(
                period_params[f"layer{i}"], cfg=cfg, spec=spec, x=h, positions=positions
            )
        return constrain_bsd(h, seq_shard=True), None

    body = period_body
    if remat:
        body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body, x, stack)
    return x


def forward_prefill(
    stack: Params,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    max_len: int,
) -> Tuple[jax.Array, Params]:
    """Forward pass that also builds the decode cache."""
    batch, s, _ = x.shape

    def period_body(carry, period_params):
        h = constrain_bsd(carry, seq_shard=True)
        cache_out: Params = {}
        for i, spec in enumerate(cfg.period):
            p = period_params[f"layer{i}"]
            hn = L.rmsnorm(p["norm_mixer"], h, cfg.norm_eps)
            if spec.mixer == "attention":
                mixed, (k, v) = L.attention(p["attn"], cfg, hn, positions)
                pad = max_len - s
                if cfg.kv_cache_dtype == "int8":
                    kq, ks = L.quantize_kv(k)
                    vq, vs = L.quantize_kv(v)
                    cache_out[f"layer{i}"] = {
                        "k": jnp.pad(kq, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        "v": jnp.pad(vq, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        "k_scale": jnp.pad(ks, ((0, 0), (0, pad))),
                        "v_scale": jnp.pad(vs, ((0, 0), (0, pad))),
                    }
                else:
                    cache_out[f"layer{i}"] = {
                        "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                        "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    }
            elif spec.mixer == "mamba":
                mixed, state = S.mamba_forward(p["mamba"], cfg, hn)
                kc = cfg.ssm_conv - 1
                conv = state["conv"]
                if conv.shape[1] < kc:
                    conv = jnp.pad(conv, ((0, 0), (kc - conv.shape[1], 0), (0, 0)))
                cache_out[f"layer{i}"] = {"h": state["h"], "conv": conv}
            else:
                mixed, state = S.rwkv6_forward(p["rwkv"], cfg, hn)
                cache_out[f"layer{i}"] = state
            h = h + mixed
            hn = L.rmsnorm(p["norm_ffn"], h, cfg.norm_eps)
            if spec.ffn == "dense":
                h = h + L.swiglu(p["ffn"], hn)
            elif spec.ffn == "moe":
                h = h + M.moe_apply(p["moe"], cfg, hn)
            else:
                h = h + S.rwkv_channel_mix(p["cmix"], hn)
        return h, cache_out

    x, cache = jax.lax.scan(period_body, x, stack)
    return x, cache


def forward_decode(
    stack: Params,
    cfg: ArchConfig,
    x: jax.Array,  # (B, 1, D)
    cache: Params,
    cache_len: jax.Array,  # scalar int32: current context length
) -> Tuple[jax.Array, Params]:
    """Single-token decode step against the cache."""
    positions = jnp.full((x.shape[0], 1), cache_len, jnp.int32)

    def period_body(carry, scanned):
        h = constrain_bsd(carry)
        period_params, period_cache = scanned
        new_cache: Params = {}
        for i, spec in enumerate(cfg.period):
            p = period_params[f"layer{i}"]
            hn = L.rmsnorm(p["norm_mixer"], h, cfg.norm_eps)
            if spec.mixer == "attention":
                c = period_cache[f"layer{i}"]
                mixed, updated = L.attention(
                    p["attn"], cfg, hn, positions,
                    kv_cache=c, cache_index=cache_len,
                )
                new_cache[f"layer{i}"] = updated
            elif spec.mixer == "mamba":
                mixed, st = S.mamba_decode_step(p["mamba"], cfg, hn, period_cache[f"layer{i}"])
                new_cache[f"layer{i}"] = st
            else:
                mixed, st = S.rwkv6_decode_step(p["rwkv"], cfg, hn, period_cache[f"layer{i}"])
                new_cache[f"layer{i}"] = st
            h = h + mixed
            hn = L.rmsnorm(p["norm_ffn"], h, cfg.norm_eps)
            if spec.ffn == "dense":
                h = h + L.swiglu(p["ffn"], hn)
            elif spec.ffn == "moe":
                h = h + M.moe_apply(p["moe"], cfg, hn)
            else:
                h = h + S.rwkv_channel_mix(p["cmix"], hn)
        return h, new_cache

    x, new_cache = jax.lax.scan(period_body, x, (stack, cache))
    return x, new_cache
