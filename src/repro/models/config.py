"""Architecture configuration.

A model is a stack of ``n_periods`` identical *periods*; each period is a
static list of :class:`LayerSpec` (mixer + ffn choice).  Dense transformers
have period length 1; Jamba's 1:7 attention:Mamba interleave with MoE on
alternate layers is a period of 8.  Parameters are stacked along the period
axis so the whole depth lowers as one ``lax.scan`` (compile time and HBM
win; see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside a period."""

    mixer: str = "attention"  # attention | mamba | rwkv6
    ffn: str = "dense"  # dense | moe | none (rwkv6 has its own channel mix)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    # Arctic-style dense FFN residual evaluated in parallel with the MoE
    dense_residual_ff: int = 0


@dataclass(frozen=True)
class ArchConfig:
    name: str = "unnamed"
    family: str = "dense"  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    d_head: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 256
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # SSM (mamba) geometry
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # modality frontend stub: number of prefix embedding positions
    prefix_len: int = 0
    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    optimizer_state_dtype: str = "float32"  # bf16 for the >300B MoE archs
    kv_cache_dtype: str = "bfloat16"  # "int8" halves+ decode-cache HBM (MHA archs)
    # pure full-attention archs skip long_500k (needs sub-quadratic mixer)
    supports_long_context: bool = False
    max_seq_len: int = 8192

    def __post_init__(self):
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by period "
            f"{len(self.period)}"
        )

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the logits dim shards on any mesh
        (MaxText-style padding; granite's 49155 -> 49408).  Padded logit
        positions are masked to -inf in ``unembed``."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.activation_dtype)

    def pdtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # output head
        for spec in self.period:
            block = 0
            if spec.mixer == "attention":
                block += d * self.n_heads * hd  # q
                block += 2 * d * self.n_kv_heads * hd  # k, v
                block += self.n_heads * hd * d  # o
            elif spec.mixer == "mamba":
                di = self.d_inner
                block += d * 2 * di  # in_proj (x, z)
                block += di * self.ssm_conv  # conv
                block += di * (2 * self.ssm_state + 1)  # B, C, dt proj
                block += di * self.ssm_state  # A
                block += di * d  # out_proj
            elif spec.mixer == "rwkv6":
                block += 4 * d * d  # r, k, v, output
                block += d * d  # gate
            if spec.ffn == "dense":
                block += 3 * d * f  # swiglu gate/up/down
            elif spec.ffn == "moe" and self.moe is not None:
                block += d * self.moe.num_experts  # router
                block += self.moe.num_experts * 3 * d * f
                if self.moe.dense_residual_ff:
                    block += 3 * d * self.moe.dense_residual_ff
            elif spec.ffn == "none" and spec.mixer == "rwkv6":
                block += 2 * d * f + d * d  # rwkv channel-mix
            block += 2 * d  # norms
            total += block * self.n_periods
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params: MoE uses top_k of num_experts."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        n_moe_layers = sum(1 for s in self.period if s.ffn == "moe") * self.n_periods
        inactive = (self.moe.num_experts - self.moe.top_k) * 3 * d * f * n_moe_layers
        return total - inactive

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test-sized config of the same family (same period
        structure, tiny dims)."""
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe,
                num_experts=min(moe.num_experts, 4),
                top_k=min(moe.top_k, 2),
                dense_residual_ff=64 if moe.dense_residual_ff else 0,
            )
        base = dataclasses.replace(
            self,
            n_layers=len(self.period) * 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128,
            vocab_size=512,
            moe=moe,
            prefix_len=min(self.prefix_len, 4),
            max_seq_len=128,
        )
        return dataclasses.replace(base, **overrides)
