"""Analytic FLOPs / bytes model per (arch x shape) -- the MODEL_FLOPS side
of the roofline's useful-compute ratio.

Per the assignment: MODEL_FLOPS = 6·N·D for training (N = params, D =
tokens; MoE uses N_active) and 2·N·D for inference shapes (no backward).
Attention's quadratic term is *excluded* from MODEL_FLOPS by that
definition -- it appears in the compiled HLO FLOPs instead, which is
exactly why the ratio is informative (ratio < 1 even for a perfect
implementation once S is large).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from .config import ArchConfig

SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def model_flops(cfg: ArchConfig, shape: str) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference)."""
    seq, batch, kind = SHAPES[shape]
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * batch


def attention_flops(cfg: ArchConfig, shape: str) -> float:
    """The quadratic attention matmuls (causal => x0.5), fwd (+2x bwd)."""
    seq, batch, kind = SHAPES[shape]
    n_attn_layers = (
        sum(1 for s in cfg.period if s.mixer == "attention") * cfg.n_periods
    )
    d_attn = cfg.n_heads * cfg.head_dim
    if kind == "decode":
        # scores + values against the full cache, one query token
        fwd = 2 * 2 * batch * seq * d_attn * n_attn_layers
        return float(fwd)
    fwd = 2 * 2 * batch * seq * seq * d_attn * n_attn_layers * 0.5
    return float(fwd * (3.0 if kind == "train" else 1.0))


def hbm_bytes_lower_bound(cfg: ArchConfig, shape: str) -> float:
    """Roofline memory floor: weights + (train) optimizer + decode cache
    traffic, per step, across the whole job."""
    seq, batch, kind = SHAPES[shape]
    n = cfg.param_count()
    p_bytes = 2.0  # bf16 weights
    if kind == "train":
        # fwd read + bwd read + grad write + optimizer read/write m,v
        opt_bytes = 2.0 if cfg.optimizer_state_dtype == "bfloat16" else 4.0
        return n * (3 * p_bytes + 4 * opt_bytes)
    if kind == "prefill":
        return n * p_bytes
    # decode: weights (active) + KV/state cache read per token
    n_attn_layers = (
        sum(1 for s in cfg.period if s.mixer == "attention") * cfg.n_periods
    )
    kv = 2 * batch * seq * cfg.n_kv_heads * cfg.head_dim * 2.0 * n_attn_layers
    return cfg.active_param_count() * p_bytes + kv
