"""Mixture-of-Experts FFN with capacity-based einsum dispatch.

Mesh-TensorFlow-style grouped dispatch: tokens are split into groups of
``group_size``; within a group each token's top-k experts are assigned a
capacity slot via cumulative sums, and dispatch/combine are one-hot
einsums.  Under pjit with experts sharded on the ``model`` axis (and groups
on ``data``) the two einsums lower to all-to-all collectives -- expert
parallelism without manual communication.  Tokens overflowing an expert's
capacity are dropped (standard Switch behaviour); ``capacity_factor``
controls the trade-off.

Arctic's dense-residual variant evaluates a small dense SwiGLU in parallel
with the MoE and sums the results.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig, MoEConfig
from .layers import dense_init, swiglu, swiglu_init

Params = Dict[str, Any]

GROUP_SIZE = 1024  # tokens per dispatch group (VMEM-friendly one-hots)


def moe_init(key, cfg: ArchConfig) -> Params:
    moe = cfg.moe
    assert moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, moe.num_experts
    dt = cfg.pdtype()
    keys = jax.random.split(key, 5)
    params = {
        "router": dense_init(keys[0], (d, e), jnp.float32),  # fp32 routing
        "w_gate": dense_init(keys[1], (e, d, f), dt),
        "w_up": dense_init(keys[2], (e, d, f), dt),
        "w_down": dense_init(keys[3], (e, f, d), dt),
    }
    if moe.dense_residual_ff:
        params["dense_residual"] = swiglu_init(keys[4], cfg, moe.dense_residual_ff)
    return params


def _capacity(moe: MoEConfig, group_tokens: int) -> int:
    cap = int(np.ceil(group_tokens * moe.top_k / moe.num_experts * moe.capacity_factor))
    return max(cap, moe.top_k)


def moe_apply(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    moe = cfg.moe
    b, s, d = x.shape
    tokens = b * s
    g_size = min(GROUP_SIZE, tokens)
    assert tokens % g_size == 0, (tokens, g_size)
    n_groups = tokens // g_size
    e = moe.num_experts
    cap = _capacity(moe, g_size)

    xg = x.reshape(n_groups, g_size, d)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (G, T, E)

    # top-k selection, then capacity slots via cumulative position
    top_probs, top_idx = jax.lax.top_k(probs, moe.top_k)  # (G, T, K)
    # normalise the k gate weights
    top_probs = top_probs / jnp.maximum(top_probs.sum(-1, keepdims=True), 1e-9)

    combine = jnp.zeros((n_groups, g_size, e, cap), x.dtype)
    # slot occupancy is computed per expert across the k selections in order
    # (k=0 has priority), matching Switch/MTF semantics
    expert_onehot_prev = jnp.zeros((n_groups, g_size, e), jnp.int32)
    for k in range(moe.top_k):
        sel = jax.nn.one_hot(top_idx[..., k], e, dtype=jnp.int32)  # (G, T, E)
        # position of this token within the expert = tokens (and earlier-k
        # picks) before it choosing the same expert
        prior = jnp.cumsum(sel, axis=1) - sel + jnp.cumsum(expert_onehot_prev, axis=1)
        pos = jnp.sum(sel * prior, axis=-1)  # (G, T)
        keep = pos < cap
        gate = (top_probs[..., k] * keep).astype(x.dtype)  # dropped tokens lose this expert
        slot = jax.nn.one_hot(pos, cap, dtype=x.dtype)  # (G, T, C)
        combine = combine + (
            gate[..., None, None] * sel[..., :, None].astype(x.dtype) * slot[..., None, :]
        )
        expert_onehot_prev = expert_onehot_prev + sel

    dispatch = (combine > 0).astype(x.dtype)  # (G, T, E, C)
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)  # (G, E, C, D)

    gate_p = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"]))
    up_p = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", gate_p * up_p, params["w_down"])

    out = jnp.einsum("gtec,gecd->gtd", combine, expert_out).reshape(b, s, d)

    if moe.dense_residual_ff:
        out = out + swiglu(params["dense_residual"], x)
    return out


def aux_load_balance_loss(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Switch-style auxiliary load-balancing loss (mean over groups)."""
    moe = cfg.moe
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    density = jnp.mean(jax.nn.one_hot(top1, moe.num_experts), axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    return moe.num_experts * jnp.sum(density * density_proxy)
