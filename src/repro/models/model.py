"""Model facade: init / train-forward / prefill / decode for any ArchConfig.

Modality frontends ([audio]/[vlm] archs) are stubs per the assignment:
``prefix_embeddings`` (precomputed frame/patch embeddings) are an input and
are prepended to the token embeddings; loss applies to token positions.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T
from ..sharding.constraints import constrain_bsd, constrain_logits
from .config import ArchConfig

Params = Dict[str, Any]


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- parameters -----------------------------------------------------------

    def init(self, key) -> Params:
        k_embed, k_stack, k_norm = jax.random.split(key, 3)
        return {
            "embedding": L.embedding_init(k_embed, self.cfg),
            "stack": T.init_stack(k_stack, self.cfg),
            "final_norm": L.rmsnorm_init(self.cfg),
        }

    # -- embedding (with modality-prefix stub) ---------------------------------

    def _embed_inputs(
        self, params: Params, tokens: jax.Array, prefix: Optional[jax.Array]
    ) -> Tuple[jax.Array, jax.Array]:
        x = L.embed(params["embedding"], tokens)  # (B, S, D)
        if prefix is not None:
            x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        x = constrain_bsd(x)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, positions

    # -- train ------------------------------------------------------------------

    def logits_train(
        self,
        params: Params,
        tokens: jax.Array,  # (B, S)
        prefix_embeddings: Optional[jax.Array] = None,  # (B, P, D)
        remat: bool = True,
    ) -> jax.Array:
        cfg = self.cfg
        x, positions = self._embed_inputs(params, tokens, prefix_embeddings)
        x = T.forward_train(params["stack"], cfg, x, positions, remat=remat)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if prefix_embeddings is not None:
            x = x[:, prefix_embeddings.shape[1] :]
        return constrain_logits(L.unembed(params["embedding"], cfg, x))

    def loss(
        self,
        params: Params,
        tokens: jax.Array,
        labels: jax.Array,
        prefix_embeddings: Optional[jax.Array] = None,
        remat: bool = True,
    ) -> jax.Array:
        logits = self.logits_train(params, tokens, prefix_embeddings, remat=remat)
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = labels >= 0
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)

    # -- serve --------------------------------------------------------------------

    def prefill(
        self,
        params: Params,
        tokens: jax.Array,  # (B, S)
        max_len: int,
        prefix_embeddings: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Params]:
        """Returns (last-position logits, decode cache)."""
        cfg = self.cfg
        x, positions = self._embed_inputs(params, tokens, prefix_embeddings)
        x, cache = T.forward_prefill(params["stack"], cfg, x, positions, max_len)
        x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
        return constrain_logits(L.unembed(params["embedding"], cfg, x)), cache

    def decode_step(
        self,
        params: Params,
        token: jax.Array,  # (B, 1)
        cache: Params,
        cache_len: jax.Array,  # scalar
    ) -> Tuple[jax.Array, Params]:
        cfg = self.cfg
        x = L.embed(params["embedding"], token)
        x, cache = T.forward_decode(params["stack"], cfg, x, cache, cache_len)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return constrain_logits(L.unembed(params["embedding"], cfg, x)), cache

    def init_cache(self, batch: int, max_len: int) -> Params:
        return T.init_cache(self.cfg, batch, max_len)
