"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Boots the engine with a reduced config, replays a batch of JSON requests
through Blaze admission, and reports latency breakdowns.
"""

from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=8)
    args = ap.parse_args()

    import jax

    from ..configs import get_config
    from ..models import Model
    from ..serve.engine import ServeConfig, ServeEngine

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        cfg, params,
        ServeConfig(batch_slots=4, max_len=128, default_max_tokens=args.max_tokens),
    )
    t0 = time.time()
    for i in range(args.requests):
        body = {"prompt": f"request {i}: the quick brown fox", "max_tokens": args.max_tokens}
        if i % 4 == 3:
            body["bad_field"] = 1  # rejected by the closed request schema
        rid, err = engine.submit(json.dumps(body))
        print(f"[serve] submit {i}: {'id=' + str(rid) if rid is not None else 'REJECTED ' + err}")
    results = engine.run_until_drained()
    dt = time.time() - t0
    s = engine.stats
    print(
        f"[serve] completed={s.completed}/{s.admitted} rejected={s.rejected} "
        f"decode_steps={s.decode_steps} wall={dt:.2f}s "
        f"validation_total={s.validation_seconds*1e6:.0f}us "
        f"({s.validation_seconds/max(s.received,1)*1e6:.1f}us/request)"
    )


if __name__ == "__main__":
    main()
