"""Input ShapeDtypeStructs for every (architecture x input-shape) cell.

``input_specs`` returns allocation-free stand-ins (weak-type-correct,
shardable) for every model input of a given shape cell.  The modality
frontends of the [audio]/[vlm] architectures are stubs: ``prefix`` is the
precomputed frame/patch embedding tensor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..models.model import Model

# The assigned shape grid (seq_len, global_batch, kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclass(frozen=True)
class Cell:
    arch: str
    shape: str

    @property
    def seq_len(self) -> int:
        return SHAPES[self.shape][0]

    @property
    def batch(self) -> int:
        return SHAPES[self.shape][1]

    @property
    def kind(self) -> str:
        return SHAPES[self.shape][2]


def cell_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic mixing (DESIGN.md §4)."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §4)"
    return True, ""


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStructs for the step function of this cell."""
    seq, batch, kind = SHAPES[shape]
    i32 = jnp.int32
    if kind == "train":
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }
        if cfg.prefix_len:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (batch, cfg.prefix_len, cfg.d_model), cfg.dtype()
            )
        return specs
    if kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
        if cfg.prefix_len:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (batch, cfg.prefix_len, cfg.d_model), cfg.dtype()
            )
        return specs
    # decode: one new token against a seq-length cache
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(batch, seq))
    return {
        "token": jax.ShapeDtypeStruct((batch, 1), i32),
        "cache": cache,
        "cache_len": jax.ShapeDtypeStruct((), i32),
    }


def abstract_params(cfg: ArchConfig):
    model = Model(cfg)
    return jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
