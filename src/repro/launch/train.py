"""Production training driver: ``python -m repro.launch.train --arch <id>``.

Wires together config -> mesh -> sharded train step -> admission pipeline
-> supervised loop (checkpoint/restart, NaN rollback, straggler watch).
On this CPU container it runs reduced configs end-to-end; on a fleet the
same driver runs the full configs (the mesh and step are identical to what
the dry-run compiles).
"""

from __future__ import annotations

import argparse
import itertools
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the smoke-scale config (CPU default)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    args = ap.parse_args()

    from ..configs import get_config
    from ..data.corpus import make_dataset
    from ..data.pipeline import ShardedPipeline
    from ..models import Model
    from ..train import optimizer as opt
    from ..train.checkpoint import CheckpointManager
    from ..train.supervisor import SupervisorConfig, TrainSupervisor
    from ..train.train_step import make_train_step
    from .mesh import make_host_mesh

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    mesh = make_host_mesh()
    ocfg = opt.OptimizerConfig(
        learning_rate=1e-3, warmup_steps=5, total_steps=args.steps,
        state_dtype=cfg.optimizer_state_dtype,
    )
    step, (psh, osh, bsh), _ = make_train_step(
        model, ocfg, mesh, batch=args.batch, donate=False
    )
    params = jax.device_put(model.init(jax.random.PRNGKey(0)), psh)
    opt_state = jax.device_put(opt.init(ocfg, params), osh)

    ds = make_dataset("driver-corpus", 2000, 6.0, 350, seed=11)
    records = [{"text": json.dumps(d)} for d in ds.documents]
    schema = {"type": "object", "required": ["text"],
              "properties": {"text": {"type": "string", "minLength": 4}}}
    pipe = ShardedPipeline(schema, records, seq_len=args.seq_len, batch_size=args.batch)

    def wrapped(p, s, b):
        prefix = None
        if cfg.prefix_len:
            prefix = jnp.zeros((args.batch, cfg.prefix_len, cfg.d_model), cfg.dtype())
        data = {"tokens": jnp.asarray(b["tokens"] % cfg.vocab_size),
                "labels": jnp.asarray(b["labels"] % cfg.vocab_size)}
        if prefix is not None:
            data["prefix"] = prefix
        return step(p, s, data)

    mgr = CheckpointManager(Path(args.ckpt_dir) / cfg.name, keep=2)
    sup = TrainSupervisor(
        wrapped, mgr, SupervisorConfig(checkpoint_every=args.checkpoint_every)
    )
    start, params, opt_state = sup.resume_or_init(params, opt_state)
    if start:
        print(f"[train] resumed from step {start}")
    params, opt_state, hist = sup.run(
        params, opt_state, itertools.cycle(pipe.batches()),
        start_step=start, num_steps=args.steps,
    )
    ok = [r for r in hist if np.isfinite(r.loss)]
    print(
        f"[train] steps={len(hist)} loss {ok[0].loss:.3f} -> {ok[-1].loss:.3f} | "
        f"admission: {pipe.admission.stats.admitted} in / "
        f"{pipe.admission.stats.rejected} rejected | "
        f"stragglers={sum(r.straggler for r in hist)}"
    )


if __name__ == "__main__":
    main()
