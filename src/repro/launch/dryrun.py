import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the step function must ``.lower().compile()`` on BOTH the single-pod
(16 data x 16 model = 256 chip) mesh and the multi-pod (2 pod x 16 x 16 =
512 chip) mesh.  Per cell we record:

* ``memory_analysis()``  -- bytes per device (proves the config fits HBM);
* ``cost_analysis()``    -- HLO FLOPs / bytes for the §Roofline terms;
* collective bytes parsed from the post-SPMD HLO text (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute), which
  cost_analysis does not report.

Results are cached as JSON under ``results/dryrun/`` -- benchmarks/roofline
and EXPERIMENTS.md read from there.

Usage:
    python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""

import argparse
import json
import re
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# v5e hardware model (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s effective per chip (1 link assumption, DESIGN.md)

_COLLECTIVE_RE = re.compile(
    r"=\s*([^=\n]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# ring-algorithm byte multipliers (bytes over links / buffer size)
_COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by collectives, by op kind (weighted)."""
    out: Dict[str, float] = {}
    raw: Dict[str, int] = {}
    count: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        raw[op] = raw.get(op, 0) + b
        count[op] = count.get(op, 0) + 1
        out[op] = out.get(op, 0.0) + b * _COLLECTIVE_FACTOR[op]
    out["_total_weighted"] = sum(v for k, v in out.items() if not k.startswith("_"))
    out["_counts"] = count  # type: ignore[assignment]
    return out


def _mesh_for(multi_pod: bool):
    from .mesh import make_production_mesh

    need = 512 if multi_pod else 256
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"dry-run needs {need} host devices; run via `python -m "
            f"repro.launch.dryrun` so XLA_FLAGS is set before jax init "
            f"(have {len(devices)})"
        )
    if multi_pod:
        return make_production_mesh(multi_pod=True)
    # single-pod mesh over the first 256 placeholder devices
    mesh_devices = np.array(devices[:256]).reshape(16, 16)
    from jax.sharding import Mesh

    return Mesh(mesh_devices, ("data", "model"))


def _scan_flops_correction(cfg, kind: str) -> float:
    """XLA cost_analysis counts a while-loop body once; the depth scan runs
    n_periods times.  Returns the multiplier to apply to scanned work.

    Conservative approach: we report both raw HLO numbers and the
    scan-corrected numbers; the correction multiplies body terms by
    (n_periods) assuming scanned work dominates (validated against the
    analytic 6ND model in benchmarks/roofline.py)."""
    return float(cfg.n_periods)


def lower_cell(arch: str, shape: str, multi_pod: bool):
    """Build + lower the step function for one cell.  Returns lowered."""
    from ..configs import get_config
    from ..models.model import Model
    from ..train import optimizer as opt
    from ..train.train_step import make_decode_step, make_prefill_step, make_train_step
    from .specs import SHAPES, cell_applicable, input_specs

    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return None, why, None
    mesh = _mesh_for(multi_pod)
    model = Model(cfg)
    seq, batch, kind = SHAPES[shape]
    specs = input_specs(cfg, shape)

    if kind == "train":
        ocfg = opt.OptimizerConfig(state_dtype=cfg.optimizer_state_dtype)
        step, (params_sh, opt_sh, _), _ = make_train_step(
            model, ocfg, mesh, batch=batch, donate=True
        )
        aparams = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        aopt = jax.eval_shape(lambda p: opt.init(ocfg, p), aparams)
        batch_specs = {k: v for k, v in specs.items()}
        lowered = step.lower(aparams, aopt, batch_specs)
    elif kind == "prefill":
        # cache must cover tokens + modality-prefix positions
        step, _, _ = make_prefill_step(
            model, mesh, batch=batch, max_len=seq + cfg.prefix_len
        )
        aparams = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        args = [aparams, specs["tokens"]]
        if cfg.prefix_len:
            args.append(specs["prefix"])
        lowered = step.lower(*args)
    else:  # decode
        step, _, _ = make_decode_step(
            model, mesh, batch=batch, max_len=seq,
            seq_sharded=(shape == "long_500k"),
        )
        aparams = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        lowered = step.lower(
            aparams, specs["token"], specs["cache"], specs["cache_len"]
        )
    return lowered, "", cfg


def run_cell(arch: str, shape: str, multi_pod: bool, *, verbose: bool = True) -> Dict[str, Any]:
    t0 = time.time()
    lowered, skip_reason, cfg = lower_cell(arch, shape, multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if lowered is None:
        return {
            "arch": arch, "shape": shape, "mesh": mesh_name,
            "status": "skipped", "reason": skip_reason,
        }
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    from .hlo_analysis import analyze_hlo

    ha = analyze_hlo(hlo)
    chips = 512 if multi_pod else 256

    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    scan_mult = _scan_flops_correction(cfg, shape)

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0))
            + int(getattr(mem, "argument_size_in_bytes", 0))
            + int(getattr(mem, "output_size_in_bytes", 0))
            - int(getattr(mem, "alias_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "cost": {
            # raw XLA numbers (while bodies counted ONCE -- see hlo_analysis)
            "xla_flops_per_device_raw": flops,
            "xla_bytes_per_device_raw": bytes_accessed,
            "scan_trip_count": cfg.n_periods,
            "transcendentals": float(cost.get("transcendentals", 0.0)),
            # trip-count-weighted static analysis (the roofline inputs)
            "dot_flops_per_device": ha.dot_flops,
            "hbm_traffic_bytes_per_device": ha.hbm_traffic_bytes,
        },
        "collectives": ha.collective_bytes,
        "collective_counts": ha.collective_counts,
        "collective_bytes_per_device": ha.total_collective_bytes,
        "while_trip_counts": ha.while_trip_counts,
        "model": {
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
        },
    }
    if verbose:
        print(
            f"[dryrun] {arch:24s} {shape:12s} {mesh_name:8s} "
            f"compile={t_compile:6.1f}s mem/dev={result['memory']['bytes_per_device']/2**30:6.2f}GiB "
            f"dotflops/dev={ha.dot_flops:.3e} coll/dev={ha.total_collective_bytes:.3e}B"
        )
    return result


def cell_path(arch: str, shape: str, multi_pod: bool) -> Path:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    return RESULTS_DIR / f"{arch}__{shape}__{mesh_name}.json"


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--arch", type=str, default=None)
    parser.add_argument("--shape", type=str, default=None)
    parser.add_argument("--all", action="store_true")
    parser.add_argument("--multi-pod-only", action="store_true")
    parser.add_argument("--single-pod-only", action="store_true")
    parser.add_argument("--force", action="store_true", help="recompute cached cells")
    args = parser.parse_args()

    from ..configs import ARCHS
    from .specs import SHAPES

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                path = cell_path(arch, shape, multi_pod)
                if path.exists() and not args.force:
                    print(f"[dryrun] cached: {path.name}")
                    continue
                try:
                    result = run_cell(arch, shape, multi_pod)
                except Exception as exc:  # noqa: BLE001 -- record and continue
                    result = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "status": "error", "error": f"{type(exc).__name__}: {exc}",
                    }
                    failures.append(result)
                    print(f"[dryrun] ERROR {arch} {shape}: {exc}")
                path.write_text(json.dumps(result, indent=2, default=str))
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed")
    print("[dryrun] all requested cells complete")


if __name__ == "__main__":
    main()
