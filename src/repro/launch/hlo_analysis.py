"""Trip-count-aware analysis of post-SPMD scheduled HLO.

XLA's ``cost_analysis()`` counts a while-loop body exactly once, which
makes it useless for scan-over-layers models (the body runs n_periods
times) -- verified empirically (see EXPERIMENTS.md §Dry-run notes).  This
module re-derives roofline inputs directly from ``compiled.as_text()``:

* builds the computation call graph (entry, while bodies/conditions,
  fusions via ``calls=``/``to_apply=``/``body=``/``condition=``);
* extracts ``known_trip_count`` from each while's backend_config and
  assigns every computation an execution **multiplier** (product of trip
  counts on the call path; conservative max over multiple call sites);
* accumulates, weighted by multiplier:
  - dot FLOPs (2 x out_elems x contracted_elems)  -> compute term
  - per-instruction HBM traffic (operands + outputs of top-level
    instructions in scheduled post-fusion HLO)    -> memory term
  - collective bytes by kind with ring factors    -> collective term

This is static analysis of the compiled artifact, not simulation: exactly
what the dry-run can honestly provide without hardware.
"""

from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,  # ring: reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# header params may contain nested tuple types -- only anchor on name + '(';
# non-entry headers are indented by one space in scheduled dumps
_COMP_HEADER = re.compile(r"^\s*(?:ENTRY )?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+([\w\-]+)\(",
    re.M,
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def _operand_names(argstr: str) -> List[str]:
    """Instruction-operand names from the parenthesized argument list.

    Scheduled dumps write operands WITH their types -- ``dot(f32[64,128]{1,0}
    %lhs, ...)`` -- so a naive comma-split yields ``f32[64`` (the commas
    inside shape brackets), silently losing every operand-shape lookup:
    dot FLOPs dropped their contracted-dim factor and HBM traffic dropped
    all operand bytes.  Anchor on the ``%`` sigil instead; untyped,
    sigil-free lists fall back to the comma split.
    """
    names = _OPERAND_NAME.findall(argstr)
    if names:
        return names
    return [p.strip() for p in argstr.split(",") if p.strip()]


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * b
    return elems, nbytes


@dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class _Computation:
    name: str
    instrs: List[_Instr] = field(default_factory=list)
    calls: List[Tuple[str, Optional[int]]] = field(default_factory=list)  # (callee, trip)


def _parse_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current: Optional[_Computation] = None
    # computations are blocks `<header> { ... }` separated by blank lines;
    # a header is the first non-blank line at (or after) module start / a
    # closing `}`.  Headers can contain `=` inside /*index=N*/ comments and
    # layout braces, so structural detection beats content filters.
    expecting_header = True
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped == "}":
            expecting_header = True
            current = None
            continue
        if expecting_header:
            m = _COMP_HEADER.match(line)
            if m and stripped.endswith("{") and not m.group(1).startswith("HloModule"):
                current = _Computation(m.group(1))
                comps[current.name] = current
                expecting_header = False
                continue
            # module prologue (HloModule line, metadata tables): skip
            if "(" not in stripped or "->" not in stripped:
                continue
        if current is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        instr = _Instr(name, type_str, op, line)
        current.instrs.append(instr)
        if op == "while":
            trip = None
            tm = _TRIP.search(line)
            if tm:
                trip = int(tm.group(1))
            for cm in _CALL_ATTR.finditer(line):
                # body and condition both scale by trip count
                current.calls.append((cm.group(1), trip))
        else:
            for cm in _CALL_ATTR.finditer(line):
                current.calls.append((cm.group(1), 1))
    return comps


def _entry_name(text: str) -> Optional[str]:
    m = re.search(r"^ENTRY %?([\w.\-]+)", text, re.M)
    return m.group(1) if m else None


def _multipliers(comps: Dict[str, _Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # propagate through the call DAG (computations are acyclic in HLO)
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        comp = comps.get(order[i])
        i += 1
        if comp is None:
            continue
        m = mult[comp.name]
        for callee, trip in comp.calls:
            t = trip if trip is not None else 1
            mult[callee] = max(mult[callee], m * t)
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
    return dict(mult)


def _dot_flops(instr: _Instr, shapes: Dict[str, str]) -> float:
    """2 x output elems x contracted elems for dot/dot_general."""
    out_elems, _ = _shape_elems_bytes(instr.type_str)
    # contracted size = prod of lhs contracting dims, from operand shape
    ops = re.search(r"(?:dot|convolution)\(([^)]*)\)", instr.line)
    lhs_name = None
    if ops:
        parts = _operand_names(ops.group(1))
        if parts:
            lhs_name = parts[0]
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    contracted = 1
    if lhs_name and cdims and lhs_name in shapes:
        dims_m = _SHAPE.search(shapes[lhs_name])
        if dims_m:
            dims = [int(d) for d in dims_m.group(2).split(",") if d]
            for ci in cdims.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contracted *= dims[int(ci)]
    return 2.0 * out_elems * contracted


@dataclass
class HloAnalysis:
    dot_flops: float = 0.0
    hbm_traffic_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    total_collective_bytes: float = 0.0
    while_trip_counts: List[int] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "hbm_traffic_bytes": self.hbm_traffic_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_counts": self.collective_counts,
            "total_collective_bytes": self.total_collective_bytes,
            "while_trip_counts": self.while_trip_counts,
        }


# ops that do not touch HBM as standalone kernels (control/meta)
_NO_TRAFFIC_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "copy-start", "copy-done",
}


def analyze_hlo(text: str) -> HloAnalysis:
    comps = _parse_computations(text)
    entry = _entry_name(text)
    if entry is None or entry not in comps:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda k: len(comps[k].instrs)) if comps else ""
    mult = _multipliers(comps, entry) if comps else {}

    # fusions' *internal* computations produce no extra HBM traffic; count
    # traffic only for instructions of "top-level" computations: entry +
    # while bodies/conditions (a scheduled module runs those as kernels).
    fusion_comps = set()
    for comp in comps.values():
        for instr in comp.instrs:
            if instr.op == "fusion":
                for cm in _CALL_ATTR.finditer(instr.line):
                    fusion_comps.add(cm.group(1))
    # reductions etc. applied via to_apply are also internal
    for comp in comps.values():
        for instr in comp.instrs:
            if instr.op in ("reduce", "all-reduce", "reduce-scatter", "scatter", "sort", "map", "reduce-window"):
                for cm in _CALL_ATTR.finditer(instr.line):
                    fusion_comps.add(cm.group(1))

    out = HloAnalysis()
    shapes_global: Dict[str, str] = {}
    for comp in comps.values():
        for instr in comp.instrs:
            shapes_global[instr.name] = instr.type_str

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        top_level = comp.name not in fusion_comps
        for instr in comp.instrs:
            if instr.op in ("dot", "convolution"):
                out.dot_flops += m * _dot_flops(instr, shapes_global)
            kind = instr.op.replace("-start", "").replace("-done", "")
            if kind in _COLLECTIVE_FACTOR and not instr.op.endswith("-done"):
                _, nbytes = _shape_elems_bytes(instr.type_str)
                w = nbytes * _COLLECTIVE_FACTOR[kind] * m
                out.collective_bytes[kind] = out.collective_bytes.get(kind, 0.0) + w
                out.collective_counts[kind] = out.collective_counts.get(kind, 0) + 1
                out.total_collective_bytes += w
            if top_level and instr.op not in _NO_TRAFFIC_OPS:
                _, out_b = _shape_elems_bytes(instr.type_str)
                in_b = 0
                args = re.search(r"\(([^)]*)\)", instr.line.split("=", 1)[1])
                if args:
                    for a in _operand_names(args.group(1)):
                        if a in shapes_global:
                            _, b = _shape_elems_bytes(shapes_global[a])
                            in_b += b
                out.hbm_traffic_bytes += m * (out_b + in_b)
        for callee, trip in comp.calls:
            if trip is not None and trip > 1:
                out.while_trip_counts.append(trip)
    return out
