"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Mesh shapes:
  single-pod: (data=16, model=16)        -- 256 chips (one v5e pod)
  multi-pod : (pod=2, data=16, model=16) -- 512 chips across DCI

The ``pod`` axis composes with ``data`` for hierarchical data parallelism
(gradient reduce-scatter crosses ICI first, then DCI) and is the pipeline
axis when pipeline parallelism is enabled.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (smoke tests use (1, 1) or (1, 2) CPU meshes)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch (pure-DP axes)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes used for parameter (FSDP/ZeRO) sharding."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
