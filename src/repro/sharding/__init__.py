"""Sharding rules: parameter/activation PartitionSpecs per mesh."""

from .rules import (
    activation_specs,
    cache_pspec,
    cache_specs_tree,
    param_pspecs,
    shard_params,
)

__all__ = [
    "param_pspecs",
    "activation_specs",
    "cache_pspec",
    "cache_specs_tree",
    "shard_params",
]
