"""Logical-axis -> mesh-axis sharding rules (DP / TP / EP / SP / pod).

Megatron-style tensor parallelism on the ``model`` axis, FSDP/ZeRO-style
parameter+optimizer sharding on the (``pod``, ``data``) axes, expert
parallelism for MoE weights (experts on ``model``, expert-FFN input dim on
FSDP), sequence parallelism for long-context decode caches.

Rules are name-based over pytree paths and *divisibility-checked*: a rule
axis that does not divide the actual dimension is dropped (e.g. kv_heads=8
on a model axis of 16 -> kv projections fall back to FSDP-only sharding).
All stacked (scan) parameters have a leading period axis that is always
replicated.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ArchConfig

# name -> per-dimension logical axes (after the leading scan axis)
# logical axes: "fsdp" (pod+data), "tensor" (model), None (replicated)
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings (not scanned: no leading period axis)
    "embedding.tokens": ("tensor", "fsdp"),
    "embedding.head": ("fsdp", "tensor"),
    # attention
    "attn.wq": ("fsdp", "tensor", None),
    "attn.wk": ("fsdp", "tensor", None),
    "attn.wv": ("fsdp", "tensor", None),
    "attn.wo": ("tensor", None, "fsdp"),
    "attn.bq": ("tensor", None),
    "attn.bk": ("tensor", None),
    "attn.bv": ("tensor", None),
    # dense FFN
    "ffn.w_gate": ("fsdp", "tensor"),
    "ffn.w_up": ("fsdp", "tensor"),
    "ffn.w_down": ("tensor", "fsdp"),
    # MoE: experts on tensor axis (EP), expert-FFN dims on fsdp
    "moe.router": ("fsdp", None),
    "moe.w_gate": ("tensor", "fsdp", None),
    "moe.w_up": ("tensor", "fsdp", None),
    "moe.w_down": ("tensor", None, "fsdp"),
    "moe.dense_residual.w_gate": ("fsdp", "tensor"),
    "moe.dense_residual.w_up": ("fsdp", "tensor"),
    "moe.dense_residual.w_down": ("tensor", "fsdp"),
    # Mamba (inner dim on tensor: conv + scan are channel-independent)
    "mamba.in_proj": ("fsdp", "tensor"),
    "mamba.conv_w": (None, "tensor"),
    "mamba.conv_b": ("tensor",),
    "mamba.x_proj": ("tensor", None),
    "mamba.dt_proj": (None, "tensor"),
    "mamba.dt_bias": ("tensor",),
    "mamba.A_log": ("tensor", None),
    "mamba.D": ("tensor",),
    "mamba.out_proj": ("tensor", "fsdp"),
    # RWKV-6
    "rwkv.w_r": ("fsdp", "tensor"),
    "rwkv.w_k": ("fsdp", "tensor"),
    "rwkv.w_v": ("fsdp", "tensor"),
    "rwkv.w_g": ("fsdp", "tensor"),
    "rwkv.w_decay": ("fsdp", "tensor"),
    "rwkv.w_o": ("tensor", "fsdp"),
    "rwkv.decay_bias": ("tensor",),
    "rwkv.bonus": (None, None),
    "rwkv.shift_mix": (None,),
    # RWKV channel mix
    "cmix.w_k": ("fsdp", "tensor"),
    "cmix.w_v": ("tensor", "fsdp"),
    "cmix.w_r": ("fsdp", "tensor"),
    "cmix.shift_mix": (None,),
}


def _logical_to_mesh(axis: Optional[str], mesh: Mesh):
    if axis is None:
        return None
    if axis == "tensor":
        return "model" if "model" in mesh.axis_names else None
    if axis == "fsdp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if axes else None
    raise ValueError(axis)


def _axis_size(mesh: Mesh, mesh_axis) -> int:
    if mesh_axis is None:
        return 1
    if isinstance(mesh_axis, tuple):
        out = 1
        for a in mesh_axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[mesh_axis]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return ".".join(parts)


def _spec_for(path_s: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    # match the longest rule suffix present in the path
    rule = None
    for name, axes in _PARAM_RULES.items():
        if path_s.endswith(name) or (name in path_s):
            rule = axes
            break
    if rule is None:
        return P()  # norms, scalars: replicated
    ndim = len(shape)
    # stacked (scan) params have one extra leading axis
    offset = ndim - len(rule)
    spec: list = [None] * ndim
    for i, logical in enumerate(rule):
        dim = offset + i
        if dim < 0:
            continue
        mesh_axis = _logical_to_mesh(logical, mesh)
        if mesh_axis is None:
            continue
        if shape[dim] % _axis_size(mesh, mesh_axis) != 0:
            continue  # divisibility fallback: replicate this dim
        spec[dim] = mesh_axis
    return P(*spec)


def param_pspecs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), np.shape(leaf), mesh), params
    )


def shard_params(params: Any, mesh: Mesh) -> Any:
    """Device-put params with their production sharding."""
    specs = param_pspecs(params, mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs
    )


# ---------------------------------------------------------------------------
# Activations / inputs
# ---------------------------------------------------------------------------


def activation_specs(
    mesh: Mesh, *, batch: int, seq_sharded: bool = False, vocab: Optional[int] = None
) -> Dict[str, P]:
    """Input/activation PartitionSpecs.

    ``seq_sharded=True`` activates sequence parallelism: used for
    long-context decode where batch < data-axis size (long_500k, B=1).
    ``vocab`` enables the logits vocab-sharding divisibility check.
    """
    b_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    total = 1
    for a in b_axes:
        total *= mesh.shape[a]
    if batch % max(total, 1) != 0:
        # batch not divisible by the DP axes: drop pod first, then data
        b_axes = tuple(a for a in b_axes[1:]) if len(b_axes) > 1 else ()
        total = 1
        for a in b_axes:
            total *= mesh.shape[a]
        if b_axes and batch % total != 0:
            b_axes = ()
    batch_spec = b_axes if b_axes else None
    seq_spec = ("data",) if (seq_sharded and "data" in mesh.axis_names) else None
    vocab_axis = "model" if "model" in mesh.axis_names else None
    if vocab is not None and vocab_axis is not None and vocab % mesh.shape["model"] != 0:
        vocab_axis = None  # odd vocab (e.g. 49155): replicate logits dim
    return {
        "tokens": P(batch_spec, None),
        "labels": P(batch_spec, None),
        "prefix": P(batch_spec, None, None),
        "logits": P(batch_spec, None, vocab_axis),
        "batch": P(batch_spec),
        "seq": P(seq_spec),
    }


def cache_pspec(mesh: Mesh, *, batch: int, seq_sharded: bool) -> Dict[str, P]:
    """Decode-cache PartitionSpecs (stacked leading period axis).

    KV tensors are (periods, B, S, KVH, Dh): batch on data when divisible,
    else sequence-parallel over data (long_500k).
    """
    b_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    total = 1
    for a in b_axes:
        total *= mesh.shape[a]
    batch_ok = batch % max(total, 1) == 0 and not seq_sharded
    model_axis = "model" if "model" in mesh.axis_names else None
    if batch_ok:
        # batch on DP axes; the model-axis dim of the KV tensor is chosen
        # per-shape in cache_specs_tree: kv heads when they divide (local
        # cache update, no collectives), else head_dim (local update,
        # cheap score all-reduce), else the sequence (SP; update reshards).
        return {
            "kv": P(None, b_axes, None, None, None),  # model dim set later
            "ssm_h": P(None, b_axes, model_axis, None),
            "ssm_conv": P(None, b_axes, None, model_axis),
            "rwkv_state": P(None, b_axes, None, None, None),
            "rwkv_x": P(None, b_axes, None),
        }
    # long-context, tiny batch: shard the sequence over data (SP); the
    # model-dim choice still applies on top
    return {
        "kv": P(None, None, ("data",) if "data" in mesh.axis_names else None, None, None),
        "ssm_h": P(None, None, model_axis, None),
        "ssm_conv": P(None, None, None, model_axis),
        "rwkv_state": P(None, None, None, None, None),
        "rwkv_x": P(None, None, None),
    }


def cache_specs_tree(cache: Any, mesh: Mesh, *, batch: int, seq_sharded: bool) -> Any:
    table = cache_pspec(mesh, batch=batch, seq_sharded=seq_sharded)

    model_size = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def pick(path, leaf):
        s = _path_str(path)
        nd = np.ndim(leaf)
        if s.endswith(".k") or s.endswith(".v"):
            base = list(table["kv"])
            shape = np.shape(leaf)  # (periods, B, S, KVH, Dh)
            if model_size > 1 and base[2] != ("data",):
                kvh, dh = shape[3], shape[4]
                if kvh % model_size == 0:
                    base[3] = "model"  # best: fully local cache updates
                elif dh % model_size == 0:
                    base[4] = "model"  # local updates + score all-reduce
                elif shape[2] % model_size == 0:
                    base[2] = "model"  # SP fallback: update reshards
            elif model_size > 1 and base[2] == ("data",):
                # long-context: seq on data; add model on heads or head_dim
                kvh, dh = shape[3], shape[4]
                if kvh % model_size == 0:
                    base[3] = "model"
                elif dh % model_size == 0:
                    base[4] = "model"
            return P(*base)
        if s.endswith("_scale"):  # int8 KV scales: (periods, B, S)
            kv = table["kv"]
            return P(*kv[:nd])
        if s.endswith(".h"):
            return table["ssm_h"]
        if s.endswith(".conv"):
            return table["ssm_conv"]
        if s.endswith(".state"):
            return table["rwkv_state"]
        if s.endswith(".x_last"):
            return table["rwkv_x"]
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(pick, cache)
