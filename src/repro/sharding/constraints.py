"""In-model activation sharding constraints.

XLA's sharding propagation is free to replicate intermediates (it did:
full-batch logits replicated 256x on the first granite lowering).
Production frameworks pin activations at layer boundaries; we do the same
with a trace-time context: step builders install the mesh + batch axes,
and the model calls :func:`constrain` at the few points that matter
(embedding output, scan carry, logits).  When no context is installed
(single-device smoke tests) the calls are no-ops.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar[Optional["ActivationCtx"]] = contextvars.ContextVar(
    "activation_sharding", default=None
)


class ActivationCtx:
    def __init__(self, mesh: Mesh, batch_axes: Tuple[str, ...], vocab_axis: Optional[str]):
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.vocab_axis = vocab_axis


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, batch: int, vocab: int):
    """Install activation constraints for the duration of a trace."""
    b_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    total = 1
    for a in b_axes:
        total *= mesh.shape[a]
    if total and batch % total != 0:
        b_axes = b_axes[1:]
        total = 1
        for a in b_axes:
            total *= mesh.shape[a]
        if b_axes and batch % total != 0:
            b_axes = ()
    vocab_axis = "model" if "model" in mesh.axis_names else None
    if vocab_axis is not None and vocab % mesh.shape["model"] != 0:
        vocab_axis = None
    token = _CTX.set(ActivationCtx(mesh, b_axes, vocab_axis))
    try:
        yield
    finally:
        _CTX.reset(token)


def _wsc(x, spec: P):
    ctx = _CTX.get()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def constrain_bsd(x, *, seq_shard: bool = False):
    """(batch, seq, d_model) activations: batch on the DP axes.

    ``seq_shard=True`` additionally shards the sequence dim on ``model``
    (Megatron sequence parallelism).  Applied to the scan carry it divides
    the saved-activation stack (L, B, S, D) by the model-axis size -- the
    difference between 62 GiB and v5e-viable 8 GiB for granite train_4k.
    XLA derives the per-layer all-gather / reduce-scatter pairs from the
    constraint.  Skipped automatically when seq is not divisible (decode).
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    batch = ctx.batch_axes if ctx.batch_axes else None
    seq_axis = None
    if (
        seq_shard
        and x.ndim >= 3
        and "model" in ctx.mesh.axis_names
        and x.shape[1] % ctx.mesh.shape["model"] == 0
        and x.shape[1] >= ctx.mesh.shape["model"]
    ):
        seq_axis = "model"
    return _wsc(x, P(batch, seq_axis, *([None] * (x.ndim - 2))))


def model_axis_divides(dim: int) -> bool:
    """True when ``dim`` is divisible by the installed mesh's model axis
    (False when no context/mesh: callers then skip the constraint)."""
    ctx = _CTX.get()
    if ctx is None or "model" not in ctx.mesh.axis_names:
        return False
    return dim % ctx.mesh.shape["model"] == 0


def constrain(x, *spec):
    """Explicit PartitionSpec constraint under the installed mesh.

    Axis entries that do not divide the corresponding dim are dropped
    (same divisibility fallback as the parameter rules); no-op when no
    activation context is installed.
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    checked = []
    for dim, axis in enumerate(spec):
        if axis is None:
            checked.append(None)
            continue
        if axis == "batch":
            axis = ctx.batch_axes if ctx.batch_axes else None
            checked.append(axis)
            continue
        size = ctx.mesh.shape[axis] if axis in ctx.mesh.axis_names else 0
        checked.append(axis if size and x.shape[dim] % size == 0 else None)
    return _wsc(x, P(*checked))


def constrain_logits(x):
    """(batch, seq, vocab): batch on DP axes, vocab on model when divisible."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    batch = ctx.batch_axes if ctx.batch_axes else None
    return _wsc(x, P(batch, None, ctx.vocab_axis))


def logits_pspec_ctx() -> Optional[P]:
    ctx = _CTX.get()
    if ctx is None:
        return None
    batch = ctx.batch_axes if ctx.batch_axes else None
    return P(batch, None, ctx.vocab_axis)
