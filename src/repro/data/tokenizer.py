"""Byte-level tokenizer for the LM substrate (offline: no external vocab).

Token ids 0..255 are raw bytes; id 256 = BOS, 257 = EOS, 258 = PAD.  The
assigned architectures have much larger vocabularies -- training examples
simply use the low id range, which exercises identical compute paths.
"""

from __future__ import annotations

from typing import Iterable, List

import numpy as np

from ..obs.profile import phase as _phase

BOS = 256
EOS = 257
PAD = 258
VOCAB = 259


def encode(text: str, *, bos: bool = True, eos: bool = True) -> List[int]:
    ids = list(text.encode("utf-8"))
    if bos:
        ids.insert(0, BOS)
    if eos:
        ids.append(EOS)
    return ids


def decode(ids: Iterable[int]) -> str:
    data = bytes(i for i in ids if 0 <= i < 256)
    return data.decode("utf-8", errors="replace")


def pack(texts: Iterable[str], seq_len: int) -> np.ndarray:
    """Pack encoded texts into (N, seq_len) rows (train-time packing)."""
    with _phase("tokenize.pack"):
        stream: List[int] = []
        for t in texts:
            stream.extend(encode(t))
        n = max(1, len(stream) // seq_len)
        stream = stream[: n * seq_len]
        if not stream:
            stream = [PAD] * seq_len
            n = 1
        return np.asarray(stream, np.int32).reshape(n, seq_len)
