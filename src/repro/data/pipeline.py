"""Sharded training-data pipeline with Blaze admission control.

The paper's deployment story made concrete: every JSON training record is
validated against the dataset schema *before* tokenization.  Validation
uses the compiled fast path -- the batched tensor executor when the schema
is in the structural subset, the sequential compiled executor otherwise --
and rejected records are counted, never trained on.

Sharding is deterministic by (host_id, num_hosts): host h takes records
where ``record_index % num_hosts == host_id``, so restarts and elastic
re-meshes replay identical shards from a step-indexed cursor (no
coordination service required -- the 1000-node-friendly choice).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..core import CompilerOptions, Validator, compile_schema
from ..core.batch_executor import BatchValidator
from ..core.tape import try_build_tape
from . import tokenizer
from .doc_table import encode_batch


@dataclass
class PipelineStats:
    seen: int = 0
    admitted: int = 0
    rejected: int = 0
    batch_validated: int = 0
    fallback_validated: int = 0


class AdmissionController:
    """Compiled-schema admission: batch fast path + sequential fallback."""

    def __init__(self, schema: Any, *, use_batch: bool = True, batch_max_nodes: int = 256):
        self.compiled = compile_schema(schema)
        self.sequential = Validator(self.compiled, engine="codegen")
        self.batch_validator = None
        self.batch_max_nodes = batch_max_nodes
        if use_batch:
            tape, reason = try_build_tape(self.compiled)
            if tape is not None:
                self.batch_validator = BatchValidator(tape, use_pallas=False)
            self.fallback_reason = reason
        self.stats = PipelineStats()

    def admit(self, records: List[Any]) -> List[bool]:
        self.stats.seen += len(records)
        results: List[Optional[bool]] = [None] * len(records)
        if self.batch_validator is not None and records:
            table = encode_batch(records, max_nodes=self.batch_max_nodes)
            valid, decided = self.batch_validator.validate(table)
            for i in range(len(records)):
                if decided[i]:
                    results[i] = bool(valid[i])
                    self.stats.batch_validated += 1
        for i, r in enumerate(results):
            if r is None:
                results[i] = self.sequential.is_valid(records[i])
                self.stats.fallback_validated += 1
        self.stats.admitted += sum(results)
        self.stats.rejected += len(results) - sum(results)
        return results  # type: ignore[return-value]


@dataclass
class ShardedPipeline:
    """Deterministic host-sharded record -> token-batch pipeline."""

    schema: Any
    records: List[Any]  # in-memory source; production: sharded files
    host_id: int = 0
    num_hosts: int = 1
    seq_len: int = 128
    batch_size: int = 8
    admission_batch: int = 64

    def __post_init__(self):
        self.admission = AdmissionController(self.schema)
        self.cursor = 0

    def _shard_records(self) -> Iterator[Tuple[int, Any]]:
        for i, rec in enumerate(self.records):
            if i % self.num_hosts == self.host_id:
                yield i, rec

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Yield {tokens, labels} batches of admitted, tokenized records."""
        buffer: List[str] = []
        pending: List[Any] = []

        def flush_pending():
            nonlocal pending
            if not pending:
                return
            oks = self.admission.admit(pending)
            for rec, ok in zip(pending, oks):
                if ok:
                    buffer.append(json.dumps(rec, sort_keys=True))
            pending = []

        for _, rec in self._shard_records():
            pending.append(rec)
            if len(pending) >= self.admission_batch:
                flush_pending()
            while True:
                packed = self._drain(buffer)
                if packed is None:
                    break
                yield packed
        flush_pending()
        while True:
            packed = self._drain(buffer, final=True)
            if packed is None:
                break
            yield packed

    def _drain(self, buffer: List[str], final: bool = False):
        need_tokens = self.seq_len * self.batch_size
        have = sum(len(t) + 2 for t in buffer)
        if have < need_tokens and not (final and buffer):
            return None
        text, rest = buffer[:], []
        packed = tokenizer.pack(text, self.seq_len)
        buffer.clear()
        if packed.shape[0] < self.batch_size:
            if not final:
                # not enough rows yet: put the text back and wait
                buffer.extend(text)
                return None
            reps = -(-self.batch_size // packed.shape[0])
            packed = np.tile(packed, (reps, 1))
        tokens = packed[: self.batch_size]
        labels = np.roll(tokens, -1, axis=1).astype(np.int32)
        labels[:, -1] = -1  # masked
        return {"tokens": tokens.astype(np.int32), "labels": labels}
