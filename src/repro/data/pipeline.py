"""Sharded training-data pipeline with Blaze admission control.

The paper's deployment story made concrete: every JSON training record is
validated against the dataset schema *before* tokenization.  Validation
uses the compiled fast path -- the batched tensor executor when the schema
is in the structural subset, the sequential compiled executor otherwise --
and rejected records are counted, never trained on.

Sharding is deterministic by (host_id, num_hosts): host h takes records
where ``record_index % num_hosts == host_id``, so restarts and elastic
re-meshes replay identical shards from a step-indexed cursor (no
coordination service required -- the 1000-node-friendly choice).
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..core import Validator
from ..core.outcomes import Verdict
from ..obs.profile import phase as _phase
from ..obs.stats import RegistryBackedStats
from ..registry import SchemaRegistry
from . import tokenizer
from .doc_table import encode_batch


class PipelineStats(RegistryBackedStats):
    """Admission counters, registry-backed (DESIGN.md §12).

    Attribute API unchanged; every field is a live counter child of a
    shared :class:`~repro.obs.metrics.MetricRegistry`, with
    ``snapshot()``/``reset()`` from the base.
    """

    PREFIX = "pipeline_"
    INT_FIELDS = (
        "seen",
        "admitted",
        "rejected",
        "batch_validated",
        "fallback_validated",
        # batchable records the depth-budgeted executor could not decide
        # (routed to the sequential oracle) -- observable, never silent.
        # ``oversize`` separately counts encoder-budget
        # (max_nodes/max_depth) overflows and ``unroll_overflow`` counts
        # documents whose recursion outran the tape's $ref-unroll
        # budget, so the three fallback causes are distinguishable
        "undecided",
        "oversize",
        "unroll_overflow",
        # fault-containment dispositions (DESIGN.md §11); all are rejects
        "rejected_guard",
        "error_isolated",
        "timed_out",
        "breaker_open",
    )
    HELP = {"seen": "records seen by the admission controller"}


class AdmissionController:
    """Compiled-schema admission: batch fast path + sequential fallback.

    Single-tenant by default (one ``schema`` on the ``endpoint`` id);
    pass a shared :class:`~repro.registry.SchemaRegistry` plus
    per-record endpoint ids to :meth:`admit` for multi-tenant admission
    over the registry's linked tape -- one batched launch for the whole
    mixed stream.  ``use_pallas``/``layout``/``max_depth`` configure the
    batched executor when the controller owns its registry (a caller-
    provided registry keeps its own settings).
    """

    def __init__(
        self,
        schema: Any = None,
        *,
        registry: Optional[SchemaRegistry] = None,
        endpoint: str = "default",
        use_batch: bool = True,
        batch_max_nodes: int = 256,
        use_pallas: bool = False,
        layout: str = "csr",
        max_depth: int = 16,
    ):
        if registry is None:
            registry = SchemaRegistry(
                use_pallas=use_pallas, layout=layout, max_depth=max_depth
            )
        self.registry = registry
        self.endpoint = endpoint
        self.use_batch = use_batch
        self.batch_max_nodes = batch_max_nodes
        if schema is not None:
            registry.register(endpoint, schema)
        elif endpoint not in registry.endpoints():
            raise ValueError(
                f"no schema given and endpoint {endpoint!r} not in the registry"
            )
        self.stats = PipelineStats(registry.metrics)

    # -- back-compat accessors (single-tenant view of the registry) ----------

    @property
    def _entry(self):
        return self.registry.get(self.endpoint)

    @property
    def compiled(self):
        return self._entry.compiled

    @property
    def sequential(self) -> Validator:
        return self._entry.validator

    @property
    def fallback_reason(self) -> str:
        return self._entry.stats.fallback_reason

    @property
    def fallback_reasons(self) -> Dict[str, str]:
        """Per-endpoint ``try_build_tape`` failure reasons (the real
        strings, not a generic "fallback" flag) for every registered
        endpoint outside the structural subset."""
        return self.registry.fallback_reasons()

    @property
    def batch_validator(self):
        """The linked-tape executor, or None when the default endpoint
        is outside the structural subset (or batching is disabled).

        NOTE: on a multi-member registry the returned executor spans all
        members -- calling ``.validate`` directly needs per-document
        ``schema_ids`` (it refuses to guess); :meth:`admit` handles that.
        """
        if not self.use_batch or self._entry.tape is None:
            return None
        return self.registry.batch_validator()

    def admit(
        self, records: List[Any], endpoints: Optional[List[str]] = None
    ) -> List[bool]:
        """Boolean-verdict admission (back-compat view of :meth:`admit_ex`)."""
        if self.use_batch:
            return [v.valid for v in self.admit_ex(records, endpoints)]
        if endpoints is None:
            endpoints = [self.endpoint] * len(records)
        if len(endpoints) != len(records):
            raise ValueError(
                f"{len(endpoints)} endpoints for {len(records)} records"
            )
        self.stats.seen += len(records)
        results = [
            self.registry.get(e).validator.is_valid(r)
            for e, r in zip(endpoints, records)
        ]
        self.stats.fallback_validated += len(records)
        self.stats.admitted += sum(results)
        self.stats.rejected += len(results) - sum(results)
        return results

    def admit_ex(
        self,
        records: List[Any],
        endpoints: Optional[List[str]] = None,
        *,
        keys: Optional[List[Any]] = None,
        explain: bool = False,
    ) -> List[Verdict]:
        """Fault-contained admission through the registry's containment
        ladder (guards -> isolated batched launch -> bounded fallback);
        one structured :class:`Verdict` per record, and ``seen`` always
        equals the sum of all disposition counters.  ``explain=True``
        opts INVALID verdicts into first-failure attribution."""
        if endpoints is None:
            endpoints = [self.endpoint] * len(records)
        self.stats.seen += len(records)
        # top-level attribution root: admit.* / encode.* / executor.* /
        # fallback.* phases nest under it, so its exclusive time is the
        # controller's own bookkeeping
        with _phase("pipeline.admit"):
            verdicts, counts = self.registry.admit_mixed_ex(
                records,
                endpoints,
                max_nodes=self.batch_max_nodes,
                keys=keys,
                explain=explain,
            )
        self.stats.batch_validated += counts.batch_validated
        self.stats.undecided += counts.undecided
        self.stats.oversize += counts.oversize
        self.stats.unroll_overflow += counts.unroll_overflow
        self.stats.fallback_validated += counts.fallback_validated
        self.stats.rejected_guard += counts.rejected_guard
        self.stats.error_isolated += counts.error_isolated
        self.stats.timed_out += counts.timed_out
        self.stats.breaker_open += counts.breaker_open
        admitted = sum(1 for v in verdicts if v.admitted)
        self.stats.admitted += admitted
        self.stats.rejected += len(verdicts) - admitted
        return verdicts


@dataclass
class ShardedPipeline:
    """Deterministic host-sharded record -> token-batch pipeline."""

    schema: Any
    records: List[Any]  # in-memory source; production: sharded files
    host_id: int = 0
    num_hosts: int = 1
    seq_len: int = 128
    batch_size: int = 8
    admission_batch: int = 64

    def __post_init__(self):
        self.admission = AdmissionController(self.schema)
        self.cursor = 0

    def _shard_records(self) -> Iterator[Tuple[int, Any]]:
        for i, rec in enumerate(self.records):
            if i % self.num_hosts == self.host_id:
                yield i, rec

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Yield {tokens, labels} batches of admitted, tokenized records."""
        buffer: List[str] = []
        pending: List[Any] = []

        def flush_pending():
            nonlocal pending
            if not pending:
                return
            oks = self.admission.admit(pending)
            for rec, ok in zip(pending, oks):
                if ok:
                    buffer.append(json.dumps(rec, sort_keys=True))
            pending = []

        for _, rec in self._shard_records():
            pending.append(rec)
            if len(pending) >= self.admission_batch:
                flush_pending()
            while True:
                packed = self._drain(buffer)
                if packed is None:
                    break
                yield packed
        flush_pending()
        while True:
            packed = self._drain(buffer, final=True)
            if packed is None:
                break
            yield packed

    def _drain(self, buffer: List[str], final: bool = False):
        need_tokens = self.seq_len * self.batch_size
        have = sum(len(t) + 2 for t in buffer)
        if have < need_tokens and not (final and buffer):
            return None
        text, rest = buffer[:], []
        packed = tokenizer.pack(text, self.seq_len)
        buffer.clear()
        if packed.shape[0] < self.batch_size:
            if not final:
                # not enough rows yet: put the text back and wait
                buffer.extend(text)
                return None
            reps = -(-self.batch_size // packed.shape[0])
            packed = np.tile(packed, (reps, 1))
        tokens = packed[: self.batch_size]
        labels = np.roll(tokens, -1, axis=1).astype(np.int32)
        labels[:, -1] = -1  # masked
        return {"tokens": tokens.astype(np.int32), "labels": labels}
