"""Data plane: JSON token tables, synthetic corpus, sharded pipeline."""
