"""JSON documents as columnar token tables (the TPU-native document form).

The paper's C++ executor chases pointers through a DOM; a TPU wants flat,
fixed-shape tensors.  We encode each parsed document as struct-of-arrays in
**BFS order**, which guarantees (a) a node's parent precedes it, and (b) the
children of every node are *contiguous* -- property matching and item loops
become range scans.  Key/string hashes are computed at encode time, exactly
as the paper computes hashes during parsing (§4.1).

Long-string caveat: the paper resolves long-string (>31 byte) hash
collisions with a full string comparison.  The batched executor cannot
pointer-chase into variable-length strings, so long strings additionally
carry a 64-bit FNV-1a hash in lanes 6-7 (which the paper's scheme leaves
zero).  A residual collision needs identical length, first/last byte, *and*
FNV64 -- probability ~2^-64.  The sequential executor remains the exact
conformance oracle.  This deviation is recorded in DESIGN.md §7.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.doc_model import HashedObject
from ..core.hashing import SHORT_LIMIT, hash_lanes, shash_bytes
from ..core.nodetypes import TYPE_CODES
from ..core.outcomes import fault_point
from ..obs.profile import phase as _phase, profiler_armed as _profiler_armed

__all__ = ["TokenTable", "encode_document", "encode_batch", "key_lanes", "TYPE_CODES"]

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3


def _fnv64(data: bytes) -> int:
    h = _FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * _FNV_PRIME) & 0xFFFFFFFFFFFFFFFF
    return h


def key_lanes(s: str) -> np.ndarray:
    """8x uint32 lanes for a key/string: the paper's semi-perfect hash, with
    FNV64 strengthening in lanes 6-7 for long strings (batch mode only)."""
    data = s.encode("utf-8")
    lanes = hash_lanes(shash_bytes(data))
    if len(data) > SHORT_LIMIT:
        fnv = _fnv64(data)
        lanes = lanes.copy()
        lanes[6] = (fnv >> 32) & 0xFFFFFFFF
        lanes[7] = fnv & 0xFFFFFFFF
    return lanes


def _str_prefix8(data: bytes) -> Tuple[int, int]:
    padded = data[:8].ljust(8, b"\x00")
    return (
        int.from_bytes(padded[:4], "big"),
        int.from_bytes(padded[4:], "big"),
    )


@dataclass
class TokenTable:
    """Columnar encoding of a batch of documents, shape (B, N) per column."""

    node_type: np.ndarray  # int8   (B, N)
    is_int: np.ndarray  # bool     (B, N)
    num: np.ndarray  # float64    (B, N)   numeric value / bool as 0,1
    size: np.ndarray  # int32     (B, N)   str bytes / arr len / obj props
    parent: np.ndarray  # int32   (B, N)   -1 for root
    depth: np.ndarray  # int32    (B, N)
    idx_in_parent: np.ndarray  # int32 (B, N)  array index or object slot
    child_start: np.ndarray  # int32 (B, N)  BFS-contiguous children
    key_hash: np.ndarray  # uint32 (B, N, 8)  hash of member key (else 0)
    str_hash: np.ndarray  # uint32 (B, N, 8)  hash of string value (else 0)
    str_prefix: np.ndarray  # uint32 (B, N, 2)  first 8 bytes of string value
    str_last: np.ndarray  # uint32 (B, N)  last byte of string value
    n_nodes: np.ndarray  # int32  (B,)
    ok: np.ndarray  # bool (B,)  encoded within budget
    # row index -> error message for rows whose *encode* raised (isolated
    # faults, not budget overflows); those rows also have ok=False.
    errors: Dict[int, str] = field(default_factory=dict)

    @property
    def batch(self) -> int:
        return self.node_type.shape[0]

    @property
    def max_nodes(self) -> int:
        return self.node_type.shape[1]

    def columns(self) -> Dict[str, np.ndarray]:
        return {
            "node_type": self.node_type,
            "is_int": self.is_int,
            "num": self.num,
            "size": self.size,
            "parent": self.parent,
            "depth": self.depth,
            "idx_in_parent": self.idx_in_parent,
            "child_start": self.child_start,
            "key_hash": self.key_hash,
            "str_hash": self.str_hash,
            "str_prefix": self.str_prefix,
            "str_last": self.str_last,
            "n_nodes": self.n_nodes,
            "ok": self.ok,
        }

    def take(self, rows: Sequence[int]) -> "TokenTable":
        """Row-slice a sub-batch (used by the bisecting launch isolator)."""
        idx = np.asarray(rows, np.int64)
        cols = {k: v[idx] for k, v in self.columns().items()}
        remap = {int(r): j for j, r in enumerate(idx)}
        errs = {remap[r]: m for r, m in self.errors.items() if r in remap}
        return TokenTable(errors=errs, **cols)


def _items_of(value: Any):
    if isinstance(value, HashedObject):
        return value.items()
    return list(value.items())


def encode_document(
    doc: Any,
    max_nodes: int = 256,
    max_depth: int = 16,
    hash_fn: Callable[[str], np.ndarray] = key_lanes,
) -> Optional[Dict[str, np.ndarray]]:
    """Encode one parsed JSON value into single-document columns (N,).

    Returns None when the document exceeds the node or depth budget
    (callers fall back to the sequential executor).  ``hash_fn``
    computes the 8-lane key/string hash; an armed profiler swaps in a
    timed wrapper (``encode_batch``) so the walk/hash split is
    attributable without taxing the disarmed path.
    """
    cols = {
        "node_type": np.zeros(max_nodes, np.int8),
        "is_int": np.zeros(max_nodes, bool),
        "num": np.zeros(max_nodes, np.float64),
        "size": np.zeros(max_nodes, np.int32),
        "parent": np.full(max_nodes, -1, np.int32),
        "depth": np.zeros(max_nodes, np.int32),
        "idx_in_parent": np.full(max_nodes, -1, np.int32),
        "child_start": np.zeros(max_nodes, np.int32),
        "key_hash": np.zeros((max_nodes, 8), np.uint32),
        "str_hash": np.zeros((max_nodes, 8), np.uint32),
        "str_prefix": np.zeros((max_nodes, 2), np.uint32),
        "str_last": np.zeros(max_nodes, np.uint32),
    }
    # BFS queue of (value, parent_idx, depth, key(str|None), idx_in_parent)
    queue: List[Tuple[Any, int, int, Optional[str], int]] = [(doc, -1, 0, None, -1)]
    count = 0
    while queue:
        value, parent, depth, key, idx = queue.pop(0)
        if count >= max_nodes or depth > max_depth:
            return None
        i = count
        count += 1
        cols["parent"][i] = parent
        cols["depth"][i] = depth
        cols["idx_in_parent"][i] = idx
        if key is not None:
            cols["key_hash"][i] = hash_fn(key)
        if value is None:
            cols["node_type"][i] = TYPE_CODES["null"]
        elif isinstance(value, bool):
            cols["node_type"][i] = TYPE_CODES["boolean"]
            cols["num"][i] = 1.0 if value else 0.0
        elif isinstance(value, (int, float)):
            cols["node_type"][i] = TYPE_CODES["number"]
            cols["num"][i] = float(value)
            cols["is_int"][i] = (
                isinstance(value, int) or float(value).is_integer()
            )
        elif isinstance(value, str):
            data = value.encode("utf-8")
            cols["node_type"][i] = TYPE_CODES["string"]
            cols["size"][i] = len(value)  # code points, matching len(str)
            cols["str_hash"][i] = hash_fn(value)
            p0, p1 = _str_prefix8(data)
            cols["str_prefix"][i] = (p0, p1)
            cols["str_last"][i] = data[-1] if data else 0
        elif isinstance(value, list):
            cols["node_type"][i] = TYPE_CODES["array"]
            cols["size"][i] = len(value)
            cols["child_start"][i] = count + len(queue)
            for j, item in enumerate(value):
                queue.append((item, i, depth + 1, None, j))
        elif isinstance(value, (dict, HashedObject)):
            items = _items_of(value)
            cols["node_type"][i] = TYPE_CODES["object"]
            cols["size"][i] = len(items)
            cols["child_start"][i] = count + len(queue)
            for j, (k, v) in enumerate(items):
                queue.append((v, i, depth + 1, k, j))
        else:
            raise TypeError(f"unsupported JSON value {type(value)!r}")
    cols["n_nodes"] = np.int32(count)
    return cols


def encode_batch(
    docs: List[Any],
    max_nodes: int = 256,
    max_depth: int = 16,
    *,
    isolate: bool = False,
    keys: Optional[Sequence[Any]] = None,
) -> TokenTable:
    """Encode a batch of documents; oversize docs get ok=False rows.

    With ``isolate=True`` a per-document encode exception (including an
    injected ``"encode"`` fault and ``RecursionError`` on hostile
    nesting) is trapped into ``TokenTable.errors[row]`` instead of
    aborting the whole batch; the poisoned row becomes an all-zero
    ok=False row, so every other row encodes bit-identically to a
    poison-free run.  ``keys`` names each row at the fault seam
    (defaults to the row index).
    """
    batch = len(docs)
    stacked: Dict[str, List[np.ndarray]] = {}
    ok = np.ones(batch, bool)
    n_nodes = np.zeros(batch, np.int32)
    errors: Dict[int, str] = {}
    template = encode_document(None, max_nodes)
    zero_cols = None
    # armed profiler: walk vs hash attribution (encode.hash nests inside
    # encode.walk, so exclusive times split the encode tax); disarmed,
    # hash_fn stays the raw key_lanes and the per-key path pays nothing
    if _profiler_armed():
        def hash_fn(s: str) -> np.ndarray:
            with _phase("encode.hash"):
                return key_lanes(s)
    else:
        hash_fn = key_lanes
    for b, doc in enumerate(docs):
        with _phase("encode.walk"):
            if isolate:
                try:
                    fault_point("encode", keys[b] if keys is not None else b)
                    cols = encode_document(doc, max_nodes, max_depth, hash_fn)
                except RecursionError:
                    errors[b] = "encode recursion limit exceeded"
                    cols = None
                except Exception as exc:  # isolated per-document fault
                    errors[b] = f"{type(exc).__name__}: {exc}"
                    cols = None
            else:
                cols = encode_document(doc, max_nodes, max_depth, hash_fn)
        if cols is None:
            ok[b] = False  # budget overflow (fallback) or isolated error row
            if zero_cols is None:
                zero_cols = {
                    k: np.zeros_like(v)
                    for k, v in template.items()
                    if k != "n_nodes"
                }
            cols = dict(zero_cols)
            cols["n_nodes"] = np.int32(0)
        n_nodes[b] = cols.pop("n_nodes")
        for k, v in cols.items():
            stacked.setdefault(k, []).append(v)
    with _phase("encode.pack"):
        arrays = {k: np.stack(v) for k, v in stacked.items()}
    return TokenTable(n_nodes=n_nodes, ok=ok, errors=errors, **arrays)
