"""Deterministic synthetic benchmark corpus (offline stand-in for Table 3).

The paper evaluates on 38 schemastore datasets; offline, we regenerate a
corpus matching Table 3's *distribution*: per-dataset schema size (KB),
document count, and mean document size (bytes).  Schemas and documents are
built in tandem -- every generator node knows both its schema dict and how
to sample valid instances -- so documents validate by construction (spot-
checked against the naive interpreter in tests/test_corpus.py).

Key-length distribution follows the paper's observation (§4.1): 95% of
keys <= 13 chars, >98% < 32 chars, the rest longer (exercising the
semi-perfect hash long path).
"""

from __future__ import annotations

import json
import random
import string
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

# (name, n_docs, schema_kb, avg_doc_bytes) -- Table 3
TABLE3 = [
    ("ansible-meta", 333, 36.1, 312), ("aws-cdk", 483, 0.7, 1145),
    ("babelrc", 794, 6.5, 140), ("clang-format", 133, 54.2, 336),
    ("cmake-presets", 967, 84.0, 2721), ("code-climate", 2484, 5.9, 282),
    ("cql2", 109, 17.9, 125), ("cspell", 981, 125.6, 817),
    ("cypress", 981, 16.0, 401), ("deno", 987, 22.4, 1018),
    ("dependabot", 967, 9.4, 403), ("draft-04", 563, 4.0, 12631),
    ("fabric-mod", 911, 11.1, 691), ("geojson", 500, 45.0, 52433),
    ("gitpod-configuration", 986, 13.1, 354), ("helm-chart-lock", 3888, 1.5, 342),
    ("importmap", 964, 0.6, 630), ("jasmine", 980, 3.6, 133),
    ("jsconfig", 981, 59.5, 177), ("jshintrc", 966, 11.8, 429),
    ("krakend", 47, 377.7, 2431), ("lazygit", 280, 87.8, 276),
    ("lerna", 985, 4.6, 172), ("nest-cli", 1025, 18.9, 290),
    ("omnisharp", 987, 13.5, 595), ("openapi", 107, 32.5, 165548),
    ("pre-commit-hooks", 985, 9.6, 549), ("pulumi", 3807, 7.7, 251),
    ("semantic-release", 794, 3.3, 460), ("stale", 961, 3.7, 466),
    ("stylecop", 983, 11.5, 567), ("tmuxinator", 382, 4.4, 628),
    ("ui5", 942, 94.1, 487), ("ui5-manifest", 611, 383.5, 2356),
    ("unreal-engine-uproject", 859, 10.6, 394), ("vercel", 710, 37.2, 406),
    ("yamllint", 984, 25.5, 351),
    ("importmap-extended", 400, 2.1, 380),  # 38th: rounds the corpus out
]

D7 = "http://json-schema.org/draft-07/schema#"
D2020 = "https://json-schema.org/draft/2020-12/schema"

_WORDS = (
    "name version type config enabled options path url target source mode "
    "value kind format level rules settings entries items files exclude "
    "include pattern timeout retries port host label tag env command args "
    "description id key output input schema plugin preset extends hooks "
    "dependencies scripts registry scope engine strict debug cache"
).split()


def _key(rng: random.Random) -> str:
    """Keys matching the paper's length distribution."""
    r = rng.random()
    base = rng.choice(_WORDS)
    if r < 0.80:
        return base  # short
    if r < 0.95:
        return base + "-" + rng.choice(_WORDS)  # <= ~13 chars mostly
    if r < 0.985:
        return base + "_" + rng.choice(_WORDS) + "_" + rng.choice(_WORDS)
    return "x-" + "-".join(rng.choice(_WORDS) for _ in range(5))  # >31 bytes


@dataclass
class _Node:
    """A schema fragment + sampler of valid instances."""

    schema: Any
    sample: Callable[[random.Random], Any]


def _string_node(rng: random.Random) -> _Node:
    r = rng.random()
    if r < 0.25:
        pat = rng.choice(["^x-", ".*", ".+", "^.{2,16}$"])
        schema = {"type": "string", "pattern": pat}

        def sample(rr):
            body = "".join(rr.choice(string.ascii_lowercase) for _ in range(rr.randint(2, 12)))
            return ("x-" + body) if pat == "^x-" else (body or "ab")

        return _Node(schema, sample)
    if r < 0.5:
        lo, hi = rng.randint(0, 3), rng.randint(8, 40)
        return _Node(
            {"type": "string", "minLength": lo, "maxLength": hi},
            lambda rr: "".join(
                rr.choice(string.ascii_lowercase) for _ in range(rr.randint(max(lo, 1), hi))
            ),
        )
    if r < 0.7:
        values = [rng.choice(_WORDS) for _ in range(rng.randint(2, 6))]
        return _Node({"enum": sorted(set(values))}, lambda rr, v=tuple(sorted(set(values))): rr.choice(v))
    return _Node({"type": "string"}, lambda rr: rr.choice(_WORDS))


def _number_node(rng: random.Random) -> _Node:
    if rng.random() < 0.5:
        lo, hi = rng.randint(-10, 0), rng.randint(1, 1000)
        return _Node(
            {"type": "integer", "minimum": lo, "maximum": hi},
            lambda rr: rr.randint(lo, hi),
        )
    return _Node({"type": "number"}, lambda rr: round(rr.uniform(-100, 100), 3))


def _bool_node(rng: random.Random) -> _Node:
    return _Node({"type": "boolean"}, lambda rr: rr.random() < 0.5)


def _array_node(rng: random.Random, item: _Node, max_items: int = 6) -> _Node:
    schema = {"type": "array", "items": item.schema}
    if rng.random() < 0.3:
        schema["maxItems"] = max_items * 2

    def sample(rr):
        return [item.sample(rr) for _ in range(rr.randint(0, max_items))]

    return _Node(schema, sample)


def _object_node(rng: random.Random, depth: int, breadth: int) -> _Node:
    n_props = rng.randint(2, breadth)
    props: Dict[str, _Node] = {}
    for _ in range(n_props):
        key = _key(rng)
        if key in props:
            continue
        props[key] = _value_node(rng, depth - 1, breadth)
    required = sorted(rng.sample(list(props), k=min(len(props), rng.randint(0, 2))))
    closed = rng.random() < 0.4
    schema: Dict[str, Any] = {
        "type": "object",
        "properties": {k: v.schema for k, v in props.items()},
    }
    if required:
        schema["required"] = required
    if closed:
        schema["additionalProperties"] = False

    def sample(rr):
        out = {}
        for k, node in props.items():
            if k in required or rr.random() < 0.55:
                out[k] = node.sample(rr)
        return out

    return _Node(schema, sample)


def _value_node(rng: random.Random, depth: int, breadth: int) -> _Node:
    if depth <= 0:
        return rng.choice([_string_node, _number_node, _bool_node])(rng)
    r = rng.random()
    if r < 0.35:
        return _object_node(rng, depth, breadth)
    if r < 0.5:
        return _array_node(rng, _value_node(rng, depth - 1, breadth))
    if r < 0.6:
        a = _object_node(rng, depth - 1, max(2, breadth // 2))
        b = _string_node(rng)
        node_schema = {"oneOf": [a.schema, b.schema]}

        def sample(rr):
            return a.sample(rr) if rr.random() < 0.5 else b.sample(rr)

        return _Node(node_schema, sample)
    return rng.choice([_string_node, _number_node, _bool_node])(rng)


@dataclass
class Dataset:
    name: str
    schema: Any
    documents: List[Any]
    dialect: str

    @property
    def schema_bytes(self) -> int:
        return len(json.dumps(self.schema).encode())

    @property
    def avg_doc_bytes(self) -> float:
        if not self.documents:
            return 0.0
        return sum(len(json.dumps(d).encode()) for d in self.documents) / len(self.documents)


def make_dataset(
    name: str,
    n_docs: int,
    schema_kb: float,
    avg_doc_bytes: float,
    *,
    seed: Optional[int] = None,
    scale: float = 1.0,
) -> Dataset:
    """Grow a schema to ~schema_kb and sample ~n_docs valid documents."""
    rng = random.Random(seed if seed is not None else hash(name) & 0xFFFF)
    dialect = D2020 if name in ("cql2", "openapi") else D7
    breadth = 6
    depth = 2 if avg_doc_bytes < 1000 else 3

    nodes: List[Tuple[str, _Node]] = []
    defs: Dict[str, Any] = {}
    root_props: Dict[str, Any] = {}
    target = schema_kb * 1024

    # shared definition exercised via many $refs (tests label/jump paths)
    shared = _object_node(rng, 1, 4)
    defs["common"] = shared.schema
    ref_count = 0

    def current_size() -> int:
        return len(json.dumps({"properties": root_props, "definitions": defs}).encode())

    while current_size() < target:
        key = _key(rng)
        if key in root_props:
            continue
        if rng.random() < 0.15 and ref_count < 8:
            root_props[key] = {"$ref": "#/definitions/common"}
            nodes.append((key, shared))
            ref_count += 1
            continue
        node = _value_node(rng, depth, breadth)
        root_props[key] = node.schema
        nodes.append((key, node))

    required = sorted(rng.sample([k for k, _ in nodes], k=min(2, len(nodes))))
    schema: Dict[str, Any] = {
        "$schema": dialect,
        "type": "object",
        "properties": root_props,
        "required": required,
    }
    if dialect == D7:
        schema["definitions"] = defs
    else:
        schema["$defs"] = {
            "common": {"$dynamicAnchor": "commonT", **defs["common"]}
        }
        # single-context dynamic reference (paper §3.4 static rewrite)
        first = next(k for k in root_props if root_props[k] == {"$ref": "#/definitions/common"})
        for k in list(root_props):
            if root_props[k] == {"$ref": "#/definitions/common"}:
                root_props[k] = {"$dynamicRef": "#commonT"}
    node_map = dict(nodes)

    def sample_doc(rr: random.Random) -> Any:
        out = {}
        for k in required:
            out[k] = node_map[k].sample(rr)
        target_bytes = avg_doc_bytes
        keys = [k for k, _ in nodes if k not in out]
        rr.shuffle(keys)
        for k in keys:
            if len(json.dumps(out).encode()) >= target_bytes:
                break
            out[k] = node_map[k].sample(rr)
        return out

    count = max(1, int(n_docs * scale))
    docs = [sample_doc(random.Random(rng.randint(0, 2**31))) for _ in range(count)]
    return Dataset(name, schema, docs, dialect)


def make_corpus(*, scale: float = 1.0, seed: int = 0) -> List[Dataset]:
    """The full 38-dataset benchmark corpus."""
    out = []
    for i, (name, n_docs, kb, avg) in enumerate(TABLE3):
        out.append(
            make_dataset(name, n_docs, kb, avg, seed=seed * 1000 + i, scale=scale)
        )
    return out
